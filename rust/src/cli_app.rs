//! `polylut` command-line interface — the leader entrypoint of the L3
//! coordinator.  Subcommands cover the whole toolflow:
//!
//! ```text
//! polylut list                             # artifacts discovered
//! polylut train    --id <artifact> [...]   # PJRT training loop
//! polylut compile  --id <artifact> [...]   # truth tables -> LUT6 netlist
//! polylut synth    --id <artifact> [...]   # area/timing report (Vivado substitute)
//! polylut rtl      --id <artifact> --out d # emit Verilog
//! polylut serve    --id <artifact> [...]   # batching inference server (stdin driver)
//! ```

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use crate::util::cli::Args;

pub fn cli_main() -> Result<()> {
    let args = Args::from_env(&["verbose", "force", "help"])?;
    if args.flag("help") || args.positional.is_empty() {
        print_help();
        return Ok(());
    }
    match args.positional[0].as_str() {
        "list" => cmd_list(&args),
        "train" => cmd_train(&args),
        "compile" => cmd_compile(&args),
        "synth" => cmd_synth(&args),
        "rtl" => cmd_rtl(&args),
        "serve" => cmd_serve(&args),
        "shard-worker" => cmd_shard_worker(&args),
        "verify" => cmd_verify(&args),
        "report" => cmd_report(&args),
        other => bail!("unknown subcommand {other:?} (try --help)"),
    }
}

fn print_help() {
    println!(
        "polylut — PolyLUT-Add toolflow (train / LUT-compile / synth / RTL / serve)\n\n\
         USAGE: polylut <subcommand> [options]\n\n\
         SUBCOMMANDS\n\
           list                          discovered artifact manifests\n\
           train    --id <artifact>      run the PJRT training loop\n\
                    [--steps N] [--restarts N] [--seed N] [--verbose]\n\
           compile  --id <artifact>      generate truth tables + LUT6 netlist\n\
                    [--netlist-opt none|fold|fold+dc|all]  optimization\n\
                    pipeline between mapping and the engines (default\n\
                    fold+dc; env POLYLUT_NETLIST_OPT): cross-LUT folding,\n\
                    + don't-care propagation from unreachable quantizer\n\
                    codes (both bit-exact), `all` adds structured\n\
                    sub-neuron pruning (accuracy-affecting opt-in; its\n\
                    agreement delta vs unpruned tables is printed).\n\
                    Prints the per-layer ops-before/after table.\n\
           synth    --id <artifact>      area/timing/pipeline report\n\
                    [--strategy 1|2]\n\
           rtl      --id <artifact> --out <dir>   emit Verilog + testbench\n\
                    [--netlist-opt LEVEL]  as for compile — the emitted RTL\n\
                    executes the same optimized netlists as the engines\n\
           serve    --id <artifact>      batching inference server (self-driving load test)\n\
                    [--backend lut|pjrt] [--batch-window-us N] [--max-batch N]\n\
                    [--requests N] [--clients N]\n\
                    [--lanes N|widest]  bitslice lane width: samples retired\n\
                    per op-stream walk (64/128/256/512; default: widest the\n\
                    host supports — avx512f→512, avx2→256, else 128; env\n\
                    POLYLUT_LANES).  64 forces the canonical scalar engine;\n\
                    the wire/shard handoff stays 64-bit planes regardless.\n\
                    [--bitslice-threshold N]  batch size from which the LUT\n\
                    backend runs bitsliced (0 = always; default: two full\n\
                    words at the active lane width, so e.g. 512 at --lanes\n\
                    256).  Smaller batches use the plan engine — or, with\n\
                    [--shards N]  (default 1), the intra-sample sharded\n\
                    engines: each request's forward pass itself runs across\n\
                    N cores with bit-plane handoff (see ARCHITECTURE.md §4).\n\
                    [--shard-hosts a:p,b:p,…]  place shard i on a remote\n\
                    `shard-worker` at entry i (`local`/`-`/empty and unlisted\n\
                    shards stay local; duplicate addresses are rejected at\n\
                    parse time) — bit-planes cross the wire, outputs stay\n\
                    bit-exact (ARCHITECTURE.md §7).\n\
                    [--shard-spin-us N]  worker epoch spin budget before the\n\
                    condvar sleep (default: 20 local, 0 with remote shards;\n\
                    env POLYLUT_SHARD_SPIN_US).\n\
                    [--wire-window N]  epochs in flight per remote session\n\
                    ahead of the last applied result (default 4; 1 = lock-\n\
                    step pacing; 0 is rejected; each session runs at the\n\
                    max of both ends' windows).\n\
                    [--wire-mux on|off]  per-host link multiplexing\n\
                    (default on): every (engine, shard) session to one\n\
                    worker host shares a single TCP connection with\n\
                    session-id demux and one reconnect/resume ladder per\n\
                    host; off restores the v2 one-connection-per-session\n\
                    topology (see ARCHITECTURE.md §7.6).\n\
                    [--wire-retries N]  reconnect-and-resume attempts per\n\
                    link incident (default 6) before the engine faults and\n\
                    routing degrades to the in-process plan.\n\
                    [--replicas N]  serve through the replica fleet: N\n\
                    in-process workers share the compiled model behind a\n\
                    deadline-aware batch former (requires --backend lut;\n\
                    --max-batch sets the pack target, 0/unset = the active\n\
                    lane width; see ARCHITECTURE.md §9).\n\
                    [--batch-deadline-us N]  oldest-request budget before a\n\
                    partial batch dispatches (default 200; fleet only).\n\
                    [--queue-depth N]  bounded admission queue (default 4096);\n\
                    admission beyond it fails fast, aged-out requests shed.\n\
                    Metrics snapshot: plan/bitslice/sharded = batches served\n\
                    per engine; shard_cells/shard_waits = per-shard occupancy\n\
                    and handoff-wait counters (cumulative); shard_spin_us and\n\
                    wire_frames/bytes/wait_ns/reconnects plus\n\
                    wire_inflight_epochs/inflight_flights/resumes/\n\
                    resume_replayed/resume_skipped/retry_exhausted and the\n\
                    per-host wire_links/wire_sessions_per_link/wire_hosts\n\
                    rollup when remote shards are active;\n\
                    fleet_replicas/formed/batch_hist/queue_hwm/shed/\n\
                    replica_faults when the fleet is active;\n\
                    simd/lanes = detected kernel level + active lane width;\n\
                    netlist_opt + netlist_ops_before/after = optimization\n\
                    level and word-op delta of the served model.\n\
                    [--netlist-opt none|fold|fold+dc|all]  netlist\n\
                    optimization level (default fold+dc, bit-exact; env\n\
                    POLYLUT_NETLIST_OPT) — see compile\n\
           shard-worker --listen H:P --shards S   host shards of a model for\n\
                    a remote coordinator (one connection per coordinator\n\
                    host carries every (engine, shard) session, demuxed by\n\
                    the session id each Hello claims after the\n\
                    model-fingerprint + resume-epoch handshake;\n\
                    `serve --shard-hosts` lists one address per remote\n\
                    shard).  [--wire-window N]  sizes the windowed\n\
                    stream's pending-frame buffer in epochs (default 4;\n\
                    0 is rejected; sessions honor the larger of this and\n\
                    the coordinator's window).  Model source: --id <artifact>,\n\
                    or --widths 8,6,3 [--net-seed N] [--beta-in B] [--beta B]\n\
                    [--beta-out B] [--fan-in F] [--fan F] [--degree D] [--a A]\n\
                    [--classes C] for a random-weight geometry (tests/benches).\n\
                    [--netlist-opt LEVEL]  table-level rewrites must match\n\
                    the coordinator's (the fingerprint handshake enforces it)\n\
           verify   (--id <artifact> | --widths w0,w1,…)   compile every\n\
                    artifact kind and run the static checkers: plan layout,\n\
                    bitslice + per-shard op streams, hazard schedules,\n\
                    wire plans and epoch-ring slot layouts.\n\
                    [--shards N] (default 2) sets the sharded\n\
                    geometry; the same --widths model knobs as shard-worker\n\
                    apply.  Prints a per-artifact report; exits nonzero on\n\
                    any violation.  (The same checkers gate every compile in\n\
                    debug builds, and in release when POLYLUT_VERIFY=1.)\n\
                    [--netlist-opt LEVEL]  also checks the folded netlists\n\
                    against their unfolded baseline (random-vector\n\
                    equivalence, reference-walk oracle) and prints the\n\
                    per-layer ops-before/after table\n\
           report   --id <artifact>      full markdown report (synth + cubes)\n\n\
         COMMON\n\
           --artifacts <dir>             artifact directory (default: artifacts)"
    );
}

pub fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get_or("artifacts", "artifacts"))
}

fn cmd_list(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let manifests = crate::meta::discover(&dir)
        .with_context(|| format!("no artifacts in {} — run `make artifacts`", dir.display()))?;
    println!("{:<24} {:>8} {:>4} {:>4} {:>3} {:>8} {}", "id", "dataset", "D", "A", "L", "tables", "widths");
    for p in manifests {
        let m = crate::meta::Manifest::load(&p)?;
        println!(
            "{:<24} {:>8} {:>4} {:>4} {:>3} {:>8} {:?}",
            m.id,
            m.dataset,
            m.config.degree,
            m.config.a_factor,
            m.config.n_layers(),
            m.config.table_words_total(),
            m.config.widths
        );
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let id = args.require("id")?;
    let man = crate::meta::load_id(&dir, id)?;
    let ds = crate::data::load(&man.dataset, args.get_usize("data-seed", 0)? as u64)?;
    let opts = crate::train::TrainOptions {
        steps: args.get_usize("steps", 400)?,
        seed: args.get_usize("seed", 0)? as u64,
        restarts: args.get_usize("restarts", 1)?,
        log_every: args.get_usize("log-every", 50)?,
        verbose: args.flag("verbose"),
        ..Default::default()
    };
    let engine = crate::runtime::Engine::cpu()?;
    println!("[polylut] training {id} on {} ({} samples)…", ds.name, ds.n_train());
    let out = crate::train::train(&engine, &man, &ds, &opts)?;
    println!(
        "[polylut] done: loss {:.4}, deployed test acc {:.4} ({} restarts)",
        out.final_loss, out.test_acc, out.restarts_run
    );
    let path = crate::train::save_state_tagged(&man, &out.state, &man.dir, opts.steps)?;
    println!("[polylut] weights -> {}", path.display());
    Ok(())
}

fn cmd_compile(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let id = args.require("id")?;
    let man = crate::meta::load_id(&dir, id)?;
    let state = crate::train::load_state(&man, &man.dir)
        .context("no trained weights — run `polylut train` first")?;
    let net = man.network_from_state(&state)?;
    let workers = crate::util::pool::default_workers();
    let level = crate::lut::OptLevel::resolve(crate::lut::opt::level_from_args(args)?);
    let t0 = std::time::Instant::now();
    let tables = crate::lut::tables::compile_network(&net, workers);
    let t_tables = t0.elapsed().as_secs_f64();
    let t1 = std::time::Instant::now();
    let opt = crate::lut::optimize(&net, tables, level, workers);
    println!(
        "[polylut] {id}: {} tables ({} words) in {t_tables:.2}s; {} LUT6 / depth {} in {:.2}s",
        opt.tables.n_tables(),
        opt.tables.total_words,
        opt.mapped.total_luts(),
        opt.mapped.max_depth(),
        t1.elapsed().as_secs_f64()
    );
    print!("{}", opt.report.render_table());
    println!(
        "[polylut] netlist-opt [{}]: {} -> {} word-ops ({:.1}% saved)",
        opt.report.level,
        opt.report.ops_before(),
        opt.report.ops_after(),
        opt.report.reduction_pct()
    );
    Ok(())
}

fn cmd_synth(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let id = args.require("id")?;
    let strategy = args.get_usize("strategy", 2)?;
    let man = crate::meta::load_id(&dir, id)?;
    let state = crate::train::load_state(&man, &man.dir)
        .context("no trained weights — run `polylut train` first")?;
    let net = man.network_from_state(&state)?;
    let report = crate::fpga::synthesize(&net, strategy.try_into()?)?;
    println!("{}", report.render());
    Ok(())
}

fn cmd_rtl(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let id = args.require("id")?;
    let out = PathBuf::from(args.require("out")?);
    let man = crate::meta::load_id(&dir, id)?;
    let state = crate::train::load_state(&man, &man.dir)
        .context("no trained weights — run `polylut train` first")?;
    let net = man.network_from_state(&state)?;
    // Publish --netlist-opt before emission: the emitter resolves the
    // level itself so RTL matches what the serving engines execute.
    crate::lut::opt::level_from_args(args)?;
    let files = crate::verilog::emit_project(&net, &out)?;
    println!("[polylut] wrote {} Verilog files to {}", files.len(), out.display());
    Ok(())
}

/// Full per-model report: accuracy, tables, mapping, timing under both
/// pipeline strategies, and Espresso cube statistics for the first neuron
/// of each layer (an auditable view of the trained Boolean functions).
fn cmd_report(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let id = args.require("id")?;
    let man = crate::meta::load_id(&dir, id)?;
    let state = crate::train::load_state(&man, &man.dir)
        .context("no trained weights — run `polylut train` first")?;
    let net = man.network_from_state(&state)?;
    let ds = crate::data::load(&man.dataset, 0)?;
    let (_, acc) = crate::train::deployed_accuracy(&man, &state, &ds, 0)?;
    println!("# PolyLUT-Add report: {id}\n");
    println!("deployed test accuracy: {:.2}% on {} ({} test samples)\n", acc * 100.0, ds.name, ds.n_test());
    for strategy in [1usize, 2] {
        let r = crate::fpga::synthesize(&net, strategy.try_into()?)?;
        println!("{}", r.render());
    }
    println!("## Boolean complexity (Espresso cube statistics, neuron 0 per layer)\n");
    let tables = crate::lut::tables::compile_network(&net, crate::util::pool::default_workers());
    for (l, lt) in tables.layers.iter().enumerate() {
        let nt = &lt.neurons[0];
        for (a, t) in nt.poly.iter().enumerate() {
            if t.n_inputs <= 14 {
                let (cubes, lits) = crate::lut::espresso::table_cube_stats(t);
                println!("layer {l} sub-neuron {a}: {} inputs, {cubes} cubes, {lits} literals", t.n_inputs);
            }
        }
        if let Some(adder) = &nt.adder {
            if adder.n_inputs <= 14 {
                let (cubes, lits) = crate::lut::espresso::table_cube_stats(adder);
                println!("layer {l} adder: {} inputs, {cubes} cubes, {lits} literals", adder.n_inputs);
            }
        }
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let id = args.require("id")?;
    crate::coordinator::serve_cli(&dir, id, args)
}

/// `polylut shard-worker --listen H:P --shards S (--id X | --widths …)` —
/// host shards of a model for a remote coordinator (ROADMAP lever (d)).
/// The model is compiled locally and must be *identical* to the
/// coordinator's (same weights, shard count and build); the wire handshake
/// verifies a fingerprint of the permuted tables before serving.  Binding
/// port 0 picks a free port; the chosen address is printed on stdout
/// (`listening on …`) so parent processes can parse it.
fn cmd_shard_worker(args: &Args) -> Result<()> {
    use std::io::Write as _;

    let listen = args.require("listen")?;
    let shards = args.get_usize("shards", 2)?.max(1);
    let workers = crate::util::pool::default_workers();
    let net = network_from_args(args, "shard-worker")?;
    // Apply the same table-level rewrites the coordinator compiled with
    // (the fingerprint handshake hashes every table word, so a mismatch
    // refuses the link instead of mis-evaluating).
    let level = crate::lut::OptLevel::resolve(crate::lut::opt::level_from_args(args)?);
    let mut tables = crate::lut::tables::compile_network(&net, workers);
    crate::lut::opt::optimize_tables(&net, &mut tables, level);
    let window = args.get_usize("wire-window", crate::sim::DEFAULT_WIRE_WINDOW)?;
    if window == 0 {
        bail!(
            "--wire-window 0 is invalid: the window is counted in in-flight epochs and must \
             be ≥ 1 (1 = lock-step pacing, {} = default; each session runs at the max of \
             both ends' windows)",
            crate::sim::DEFAULT_WIRE_WINDOW
        );
    }
    let host = std::sync::Arc::new(crate::sim::ShardWorkerHost::compile_windowed(
        &net, &tables, shards, workers, window,
    ));
    let listener = std::net::TcpListener::bind(listen)
        .with_context(|| format!("bind {listen}"))?;
    let addr = listener.local_addr()?;
    println!(
        "[shard-worker] listening on {addr} shards={shards} wire-window={window} fingerprint={:016x}",
        host.fingerprint()
    );
    // Parents parse the line above from a pipe; make sure it leaves now.
    std::io::stdout().flush()?;
    host.serve(listener);
    Ok(())
}

/// Model sourcing shared by `shard-worker` and `verify`: trained weights
/// via `--id <artifact>`, or a random-weight geometry via
/// `--widths w0,w1,… [--net-seed N] [--beta-in B] [--beta B] [--beta-out B]
/// [--fan-in F] [--fan F] [--degree D] [--a A] [--classes C]`.
fn network_from_args(args: &Args, name: &str) -> Result<crate::nn::network::Network> {
    if let Some(id) = args.get("id") {
        let man = crate::meta::load_id(&artifacts_dir(args), id)?;
        let state = crate::train::load_state(&man, &man.dir)
            .context("no trained weights — run `polylut train` first")?;
        man.network_from_state(&state)
    } else if let Some(widths_csv) = args.get("widths") {
        let widths: Vec<usize> = widths_csv
            .split(',')
            .map(|w| {
                w.trim()
                    .parse::<usize>()
                    .map_err(|_| anyhow::anyhow!("--widths entry {w:?} is not a number"))
            })
            .collect::<Result<_>>()?;
        let cfg = crate::nn::config::uniform(
            name,
            &widths,
            args.get_usize("beta-in", 2)? as u32,
            args.get_usize("beta", 2)? as u32,
            args.get_usize("beta-out", 3)? as u32,
            args.get_usize("fan-in", 3)?,
            args.get_usize("fan", 3)?,
            args.get_usize("degree", 1)? as u32,
            args.get_usize("a", 2)?,
            args.get_usize("classes", 3)?,
        );
        cfg.validate()?;
        let seed = args.get_usize("net-seed", 0)? as u64;
        Ok(crate::nn::network::Network::random(&cfg, &mut crate::util::rng::Rng::new(seed)))
    } else {
        bail!("{name} needs a model: --id <artifact> or --widths w0,w1,…");
    }
}

/// `polylut verify (--id X | --widths …) [--shards N]` — compile every
/// artifact kind for the model and run the static checkers offline: the
/// decoded-table plan, the whole-model bitslice op streams, and — at the
/// requested shard count — the per-shard cone streams, both hazard
/// schedules and every shard's wire plan.  Prints one line per artifact
/// (`OK` or the violation list) and exits nonzero when anything is
/// violated, so it can anchor CI jobs and bug reports.
fn cmd_verify(args: &Args) -> Result<()> {
    let workers = crate::util::pool::default_workers();
    let shards = args.get_usize("shards", 2)?.max(1);
    let net = network_from_args(args, "verify")?;
    let level = crate::lut::OptLevel::resolve(crate::lut::opt::level_from_args(args)?);
    let t0 = std::time::Instant::now();
    let tables = crate::lut::tables::compile_network(&net, workers);
    let opt = crate::lut::optimize(&net, tables, level, workers);
    let plan = crate::sim::EvalPlan::compile(&net, &opt.tables);
    let bits = crate::sim::BitsliceNet::from_mapped(&net, &opt.tables, &opt.mapped);
    let arts = crate::sim::verify::compile_sharded_artifacts(&net, &opt.tables, shards, workers);
    let t_compile = t0.elapsed().as_secs_f64();
    let t1 = std::time::Instant::now();
    let mut report = crate::sim::verify::verify_frozen(&plan, &bits);
    if let Some(base) = &opt.baseline {
        report.section(
            "netlist-opt equivalence",
            crate::sim::verify::verify_opt(base, &opt.mapped, 0x0707_F01D),
        );
    }
    for (label, vs) in crate::sim::verify::verify_sharded(&arts).into_sections() {
        report.section(&format!("{label} (shards={shards})"), vs);
    }
    let t_verify = t1.elapsed().as_secs_f64();
    print!("{}", report.render());
    print!("{}", opt.report.render_table());
    println!(
        "[polylut] verify: {} violation(s) across {} artifact section(s) \
         (compile {t_compile:.2}s, verify {t_verify:.3}s)",
        report.total(),
        report.sections_len(),
    );
    report.gate()
}
