//! Mini property-based testing framework (proptest is not vendored).
//!
//! `check` runs a property over `cases` generated inputs; on failure it
//! re-seeds and *shrinks* by retrying the property with progressively
//! "smaller" inputs produced by the caller's generator under a shrink hint,
//! then panics with the failing seed so the case is reproducible:
//!
//! ```ignore
//! prop::check("adder decomposition", 200, |g| {
//!     let beta = g.usize_in(1, 6);
//!     ...
//!     prop::assert_prop!(lhs == rhs, "mismatch beta={beta}");
//! });
//! ```

use super::rng::Rng;

/// Generator handle passed to properties: a seeded RNG plus a size budget
/// that the shrinking loop reduces.
pub struct Gen {
    pub rng: Rng,
    /// 1.0 for the initial attempt; shrunk toward 0 on failure replays.
    pub size: f64,
}

impl Gen {
    /// Uniform usize in [lo, hi] scaled down when shrinking.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        let span = hi - lo;
        let scaled = ((span as f64 * self.size).ceil() as usize).min(span);
        lo + if scaled == 0 { 0 } else { self.rng.below(scaled + 1) }
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.rng.f64() * self.size.max(0.05)
    }

    pub fn f32_signed(&mut self, mag: f32) -> f32 {
        ((self.rng.f32() * 2.0 - 1.0) * mag) * self.size as f32
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }

    pub fn vec_f32(&mut self, len: usize, mag: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_signed(mag)).collect()
    }
}

/// Outcome of a single property evaluation.
pub enum Outcome {
    Pass,
    Fail(String),
}

/// Run `prop` over `cases` random cases. The property signals failure by
/// returning `Outcome::Fail` (use `prop_assert!`) or by panicking.
pub fn check(name: &str, cases: usize, prop: impl Fn(&mut Gen) -> Outcome) {
    let base_seed = match std::env::var("PROP_SEED") {
        Ok(s) => s.parse::<u64>().expect("PROP_SEED must be u64"),
        Err(_) => DEFAULT_SEED,
    };
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        let mut g = Gen { rng: Rng::new(seed), size: 1.0 };
        if let Outcome::Fail(msg) = prop(&mut g) {
            // Shrink: replay the same seed with smaller size budgets and
            // report the smallest still-failing configuration.
            let mut best = (1.0f64, msg);
            for &size in &[0.5, 0.25, 0.1, 0.05] {
                let mut g = Gen { rng: Rng::new(seed), size };
                if let Outcome::Fail(m) = prop(&mut g) {
                    best = (size, m);
                }
            }
            panic!(
                "property {name:?} failed (case {case}, seed {seed}, size {}):\n  {}\n\
                 reproduce with PROP_SEED={seed}",
                best.0, best.1
            );
        }
    }
}

/// Default base seed; override per run with `PROP_SEED=<u64>`.
const DEFAULT_SEED: u64 = 0x00DD_BA11;

/// Assert inside a property; returns `Outcome::Fail` with a message.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return $crate::util::prop::Outcome::Fail(format!($($fmt)*));
        }
    };
}

/// Assert approximate float equality inside a property.
#[macro_export]
macro_rules! prop_assert_close {
    ($a:expr, $b:expr, $tol:expr) => {{
        let (a, b) = ($a, $b);
        if (a - b).abs() > $tol {
            return $crate::util::prop::Outcome::Fail(format!(
                "{} = {a} vs {} = {b} (tol {})",
                stringify!($a),
                stringify!($b),
                $tol
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("add commutes", 50, |g| {
            let a = g.f32_signed(100.0);
            let b = g.f32_signed(100.0);
            prop_assert!((a + b - (b + a)).abs() < 1e-6, "a={a} b={b}");
            Outcome::Pass
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_panics_with_seed() {
        check("always fails", 3, |g| {
            let x = g.usize_in(0, 10);
            prop_assert!(x > 100, "x={x} not > 100");
            Outcome::Pass
        });
    }

    #[test]
    fn gen_ranges() {
        let mut g = Gen { rng: Rng::new(1), size: 1.0 };
        for _ in 0..100 {
            let v = g.usize_in(3, 9);
            assert!((3..=9).contains(&v));
        }
        let mut g = Gen { rng: Rng::new(1), size: 0.0 };
        assert_eq!(g.usize_in(5, 20), 5, "size 0 shrinks to lower bound");
    }
}
