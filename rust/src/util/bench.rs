//! Mini benchmark harness (criterion is not vendored).
//!
//! `cargo bench` targets in this repo are `harness = false` binaries built
//! on this module.  `Bench::measure` warms up, then collects wall-clock
//! samples until a time budget or sample count is reached and reports
//! median / mean / p95 with a simple MAD-based spread, in criterion-like
//! one-line format.  `table` renders paper-style rows (used by the
//! fig6/table2/table3/table5 benches).  [`BenchJournal`] accumulates
//! machine-readable records and, when `POLYLUT_BENCH_JSON=<path>` is set,
//! writes them as a JSON document (the micro_hotpaths bench uses it to
//! emit `BENCH_bitslice.json` for the CI bench-smoke leg).

use std::time::{Duration, Instant};

use crate::util::json::{Json, JsonObj};

#[derive(Debug, Clone)]
pub struct Stats {
    pub samples: usize,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub p95_ns: f64,
    pub mad_ns: f64,
}

impl Stats {
    pub fn throughput(&self, items: f64) -> f64 {
        items / (self.median_ns * 1e-9)
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

pub struct Bench {
    pub warmup: Duration,
    pub budget: Duration,
    pub min_samples: usize,
    pub max_samples: usize,
}

impl Default for Bench {
    fn default() -> Self {
        // POLYLUT_BENCH_QUICK=1 trims budgets for CI-style smoke runs.
        let quick = std::env::var("POLYLUT_BENCH_QUICK").is_ok();
        Self {
            warmup: Duration::from_millis(if quick { 50 } else { 300 }),
            budget: Duration::from_secs(if quick { 1 } else { 3 }),
            min_samples: 10,
            max_samples: if quick { 100 } else { 1000 },
        }
    }
}

impl Bench {
    /// Measure `f`, print a criterion-like line, return stats.
    pub fn measure<R>(&self, name: &str, mut f: impl FnMut() -> R) -> Stats {
        // Warmup.
        let t0 = Instant::now();
        while t0.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // Sample.
        let mut samples_ns: Vec<f64> = Vec::new();
        let t1 = Instant::now();
        while (t1.elapsed() < self.budget || samples_ns.len() < self.min_samples)
            && samples_ns.len() < self.max_samples
        {
            let s = Instant::now();
            std::hint::black_box(f());
            samples_ns.push(s.elapsed().as_nanos() as f64);
        }
        samples_ns.sort_by(f64::total_cmp);
        let n = samples_ns.len();
        let median = samples_ns[n / 2];
        let mean = samples_ns.iter().sum::<f64>() / n as f64;
        let p95 = samples_ns[(n as f64 * 0.95) as usize % n];
        let mut devs: Vec<f64> = samples_ns.iter().map(|x| (x - median).abs()).collect();
        devs.sort_by(f64::total_cmp);
        let mad = devs[n / 2];
        let st = Stats { samples: n, median_ns: median, mean_ns: mean, p95_ns: p95, mad_ns: mad };
        println!(
            "{name:<48} time: [{} ± {}]  p95: {}  ({} samples)",
            fmt_ns(st.median_ns),
            fmt_ns(st.mad_ns),
            fmt_ns(st.p95_ns),
            st.samples
        );
        st
    }
}

// ---------------------------------------------------------------------------
// Serve-path load generation (closed + open loop)
// ---------------------------------------------------------------------------

/// Outcome of one load-generated request, as classified by the caller's
/// request closure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoadOutcome {
    /// Answered with a result.
    Ok,
    /// Cleanly rejected or aged out under load (backpressure / shed).
    Shed,
    /// Any other failure.
    Error,
}

/// Aggregate report of one load-generator run.  Latency percentiles are
/// exact (computed over every successful request, no reservoir), in
/// microseconds; `throughput_rps` counts only successful answers.
#[derive(Debug, Clone)]
pub struct LoadReport {
    /// `"closed"` or `"open"`.
    pub mode: &'static str,
    /// Requests issued (= scheduled arrivals for the open loop).
    pub sent: usize,
    /// Requests answered with a result.
    pub ok: usize,
    /// Requests cleanly shed / rejected.
    pub shed: usize,
    /// Requests that failed any other way.
    pub errors: usize,
    /// Wall-clock seconds for the whole run.
    pub wall_s: f64,
    /// Successful answers per wall-clock second.
    pub throughput_rps: f64,
    /// Median latency, µs.
    pub p50_us: f64,
    /// 99th-percentile latency, µs.
    pub p99_us: f64,
    /// Mean latency, µs.
    pub mean_us: f64,
}

impl LoadReport {
    fn from_latencies(
        mode: &'static str,
        sent: usize,
        shed: usize,
        errors: usize,
        wall_s: f64,
        mut lat_ns: Vec<f64>,
    ) -> LoadReport {
        lat_ns.sort_by(f64::total_cmp);
        let ok = lat_ns.len();
        let pick = |q: f64| -> f64 {
            if lat_ns.is_empty() {
                return 0.0;
            }
            let i = ((lat_ns.len() as f64 - 1.0) * q).round() as usize;
            lat_ns[i] / 1e3
        };
        let mean_us = if lat_ns.is_empty() {
            0.0
        } else {
            lat_ns.iter().sum::<f64>() / ok as f64 / 1e3
        };
        LoadReport {
            mode,
            sent,
            ok,
            shed,
            errors,
            wall_s,
            throughput_rps: ok as f64 / wall_s.max(1e-9),
            p50_us: pick(0.50),
            p99_us: pick(0.99),
            mean_us,
        }
    }

    /// One human-readable summary line.
    pub fn line(&self) -> String {
        format!(
            "{}-loop: {} ok / {} shed / {} err of {} in {:.2}s — {:.0} req/s, \
             p50 {:.0} µs, p99 {:.0} µs",
            self.mode,
            self.ok,
            self.shed,
            self.errors,
            self.sent,
            self.wall_s,
            self.throughput_rps,
            self.p50_us,
            self.p99_us
        )
    }
}

/// Closed-loop load: `clients` threads each issue `per_client` requests
/// back-to-back (a new request only after the previous answer) — the
/// classic saturation measurement, where latency is pure service time and
/// the arrival rate adapts to the server.  `f(i)` runs request `i` (a
/// globally unique index) and classifies its outcome.
pub fn closed_loop_load(
    clients: usize,
    per_client: usize,
    f: impl Fn(usize) -> LoadOutcome + Sync,
) -> LoadReport {
    let clients = clients.max(1);
    let t0 = Instant::now();
    let mut lat_ns: Vec<f64> = Vec::new();
    let mut shed = 0usize;
    let mut errors = 0usize;
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let f = &f;
                s.spawn(move || {
                    let mut lat = Vec::with_capacity(per_client);
                    let (mut sh, mut er) = (0usize, 0usize);
                    for k in 0..per_client {
                        let t = Instant::now();
                        match f(c * per_client + k) {
                            LoadOutcome::Ok => lat.push(t.elapsed().as_nanos() as f64),
                            LoadOutcome::Shed => sh += 1,
                            LoadOutcome::Error => er += 1,
                        }
                    }
                    (lat, sh, er)
                })
            })
            .collect();
        for h in handles {
            let (lat, sh, er) = h.join().expect("load client panicked");
            lat_ns.extend(lat);
            shed += sh;
            errors += er;
        }
    });
    LoadReport::from_latencies(
        "closed",
        clients * per_client,
        shed,
        errors,
        t0.elapsed().as_secs_f64(),
        lat_ns,
    )
}

/// Open-loop load: a pacer schedules `total` arrivals at a fixed
/// `rate_rps` **regardless of completions** (arrivals never wait for
/// answers — the load an independent user population applies), and
/// `workers` threads service them from an unbounded queue.  Latency is
/// measured from each request's *scheduled* arrival instant, so queueing
/// delay behind a saturated server is part of the figure — coordinated
/// omission is not masked.
pub fn open_loop_load(
    rate_rps: f64,
    total: usize,
    workers: usize,
    f: impl Fn(usize) -> LoadOutcome + Sync,
) -> LoadReport {
    let workers = workers.max(1);
    let gap = Duration::from_secs_f64(1.0 / rate_rps.max(1.0));
    let (tx, rx) = std::sync::mpsc::channel::<(usize, Instant)>();
    let rx = std::sync::Mutex::new(rx);
    let t0 = Instant::now();
    let mut lat_ns: Vec<f64> = Vec::new();
    let mut shed = 0usize;
    let mut errors = 0usize;
    std::thread::scope(|s| {
        // Pacer: unbounded sends, so a saturated server never slows the
        // arrival process down (that would make it a closed loop again).
        s.spawn(move || {
            for i in 0..total {
                let due = t0 + gap.mul_f64(i as f64);
                let now = Instant::now();
                if due > now {
                    std::thread::sleep(due - now);
                }
                if tx.send((i, due)).is_err() {
                    break;
                }
            }
            // Dropping `tx` here closes the queue: workers drain what is
            // left and then see the disconnect.
        });
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                let (rx, f) = (&rx, &f);
                s.spawn(move || {
                    let mut lat = Vec::new();
                    let (mut sh, mut er) = (0usize, 0usize);
                    loop {
                        // The guard is a temporary: the lock is released at
                        // the end of the statement, before `f` runs.
                        let job = rx.lock().unwrap_or_else(|p| p.into_inner()).recv();
                        let Ok((i, due)) = job else { break };
                        match f(i) {
                            LoadOutcome::Ok => {
                                lat.push(due.elapsed().as_nanos() as f64);
                            }
                            LoadOutcome::Shed => sh += 1,
                            LoadOutcome::Error => er += 1,
                        }
                    }
                    (lat, sh, er)
                })
            })
            .collect();
        for h in handles {
            let (lat, sh, er) = h.join().expect("load worker panicked");
            lat_ns.extend(lat);
            shed += sh;
            errors += er;
        }
    });
    LoadReport::from_latencies(
        "open",
        total,
        shed,
        errors,
        t0.elapsed().as_secs_f64(),
        lat_ns,
    )
}

/// Environment variable naming the file [`BenchJournal::write_if_requested`]
/// writes (unset = no file is written).
pub const BENCH_JSON_ENV: &str = "POLYLUT_BENCH_JSON";

/// One machine-readable throughput record: a (geometry, engine, lane-width)
/// point with its samples-per-second figure derived from [`Stats`].
#[derive(Debug, Clone)]
pub struct BenchRecord {
    /// Model geometry the measurement ran on (e.g. `"nid-t4"`).
    pub geometry: String,
    /// Engine / kernel path label (e.g. `"bitslice/avx2"`, `"plan"`).
    pub engine: String,
    /// Active lane width (samples per op-stream walk; 0 = not lane-based).
    pub lanes: usize,
    /// Batch size the throughput figure is normalized over.
    pub batch: usize,
    /// Samples retired per second at the median time.
    pub samples_per_sec: f64,
    /// Median wall-clock time per measured call, nanoseconds.
    pub median_ns: f64,
}

/// One serve-path load-test record: a (geometry, fleet-config, loop-mode)
/// point from the closed+open-loop generator — the unit of the
/// `BENCH_serve.json` trajectory.
#[derive(Debug, Clone)]
pub struct ServeRecord {
    /// Model geometry the fleet served (e.g. `"nid-t4"`).
    pub geometry: String,
    /// `"closed"` or `"open"` (see [`closed_loop_load`] / [`open_loop_load`]).
    pub mode: String,
    /// Fleet replica count.
    pub replicas: usize,
    /// Batch-former target width (lanes).
    pub target_batch: usize,
    /// Batch-former deadline, µs.
    pub deadline_us: u64,
    /// Offered arrival rate, req/s (0 = closed loop: the arrival rate is
    /// set by service completion, not by a pacer).
    pub offered_rps: f64,
    /// Concurrent clients (closed loop) or service workers (open loop).
    pub clients: usize,
    /// Requests issued.
    pub requests: usize,
    /// Requests answered with a result.
    pub ok: usize,
    /// Requests cleanly shed / rejected.
    pub shed: usize,
    /// Successful answers per wall-clock second.
    pub throughput_rps: f64,
    /// Median latency, µs.
    pub p50_us: f64,
    /// 99th-percentile latency, µs.
    pub p99_us: f64,
}

/// One netlist-optimization record: a (geometry, opt-level) point pairing
/// the word-op delta with the bitslice throughput measured at that level —
/// the unit of the `BENCH_netlist.json` trajectory.
#[derive(Debug, Clone)]
pub struct NetlistRecord {
    /// Model geometry (e.g. `"nid-t4"`).
    pub geometry: String,
    /// Optimization level spelling (`"none"`, `"fold"`, `"fold+dc"`, `"all"`).
    pub level: String,
    /// Total word-ops of the mapped netlists before the pipeline.
    pub ops_before: usize,
    /// Total word-ops the engines execute after it.
    pub ops_after: usize,
    /// Bitslice samples/s measured on the level's compiled op streams.
    pub samples_per_sec: f64,
    /// Median wall-clock time per measured call, nanoseconds.
    pub median_ns: f64,
}

/// Accumulator for [`BenchRecord`]s / [`ServeRecord`]s /
/// [`NetlistRecord`]s with a JSON emitter, env-gated via
/// [`BENCH_JSON_ENV`] so normal bench runs stay file-free.
#[derive(Debug, Default)]
pub struct BenchJournal {
    records: Vec<BenchRecord>,
    serve: Vec<ServeRecord>,
    netlist: Vec<NetlistRecord>,
}

impl BenchJournal {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one throughput point; `batch` is the items-per-call figure
    /// fed to [`Stats::throughput`].
    pub fn record(&mut self, geometry: &str, engine: &str, lanes: usize, batch: usize, st: &Stats) {
        self.records.push(BenchRecord {
            geometry: geometry.to_string(),
            engine: engine.to_string(),
            lanes,
            batch,
            samples_per_sec: st.throughput(batch as f64),
            median_ns: st.median_ns,
        });
    }

    /// Record one serve-path load-test point (built by the caller from a
    /// [`LoadReport`] plus the fleet configuration it ran against).
    pub fn record_serve(&mut self, r: ServeRecord) {
        self.serve.push(r);
    }

    /// Record one netlist-optimization point (built by the caller from an
    /// `lut::opt::OptReport` plus the throughput measured at its level).
    pub fn record_netlist(&mut self, r: NetlistRecord) {
        self.netlist.push(r);
    }

    pub fn len(&self) -> usize {
        self.records.len() + self.serve.len() + self.netlist.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty() && self.serve.is_empty() && self.netlist.is_empty()
    }

    /// The journal as a JSON document:
    /// `{"schema": "polylut-bench-v1", "records": [{...}, ...]}`.
    pub fn to_json(&self) -> Json {
        let mut root = JsonObj::new();
        root.insert("schema", "polylut-bench-v1");
        let mut records: Vec<Json> = self
            .records
            .iter()
            .map(|r| {
                let mut o = JsonObj::new();
                o.insert("geometry", r.geometry.as_str());
                o.insert("engine", r.engine.as_str());
                o.insert("lanes", r.lanes);
                o.insert("batch", r.batch);
                o.insert("samples_per_sec", r.samples_per_sec);
                o.insert("median_ns", r.median_ns);
                Json::Obj(o)
            })
            .collect();
        // Serve-path records share the array; the `mode` key marks them
        // (throughput benches have `engine` instead).
        records.extend(self.serve.iter().map(|r| {
            let mut o = JsonObj::new();
            o.insert("geometry", r.geometry.as_str());
            o.insert("mode", r.mode.as_str());
            o.insert("replicas", r.replicas);
            o.insert("target_batch", r.target_batch);
            o.insert("deadline_us", r.deadline_us as usize);
            o.insert("offered_rps", r.offered_rps);
            o.insert("clients", r.clients);
            o.insert("requests", r.requests);
            o.insert("ok", r.ok);
            o.insert("shed", r.shed);
            o.insert("throughput_rps", r.throughput_rps);
            o.insert("p50_us", r.p50_us);
            o.insert("p99_us", r.p99_us);
            Json::Obj(o)
        }));
        // Netlist-opt records are marked by the `level` key.
        records.extend(self.netlist.iter().map(|r| {
            let mut o = JsonObj::new();
            o.insert("geometry", r.geometry.as_str());
            o.insert("level", r.level.as_str());
            o.insert("ops_before", r.ops_before);
            o.insert("ops_after", r.ops_after);
            o.insert("samples_per_sec", r.samples_per_sec);
            o.insert("median_ns", r.median_ns);
            Json::Obj(o)
        }));
        root.insert("records", Json::Arr(records));
        Json::Obj(root)
    }

    /// Write the journal to the path named by [`BENCH_JSON_ENV`], if set.
    /// Returns the path written to, `None` when the env var is unset or
    /// empty.  IO failures are reported, not fatal — a bench run should
    /// still print its numbers when the journal path is unwritable.
    pub fn write_if_requested(&self) -> Option<std::path::PathBuf> {
        let path = match std::env::var(BENCH_JSON_ENV) {
            Ok(p) if !p.is_empty() => std::path::PathBuf::from(p),
            _ => return None,
        };
        let text = self.to_json().to_string_pretty();
        match std::fs::write(&path, text) {
            Ok(()) => {
                println!("[bench] wrote {} records to {}", self.len(), path.display());
                Some(path)
            }
            Err(e) => {
                eprintln!("[bench] could not write {}: {e}", path.display());
                None
            }
        }
    }
}

/// Render an aligned text table (paper-style rows) to stdout.
pub fn table(title: &str, headers: &[&str], rows: &[Vec<String>]) {
    print!("{}", table_string(title, headers, rows));
}

/// [`table`], rendered into a `String` (for reports embedded in other
/// output, e.g. `lut::opt::OptReport::render_table`).
pub fn table_string(title: &str, headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut out = format!("\n=== {title} ===\n");
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let line = |cells: &[String], out: &mut String| {
        let mut s = String::new();
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!("{:<w$}  ", c, w = widths.get(i).copied().unwrap_or(8)));
        }
        out.push_str(s.trim_end());
        out.push('\n');
    };
    line(&headers.iter().map(|h| h.to_string()).collect::<Vec<_>>(), &mut out);
    line(&widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>(), &mut out);
    for row in rows {
        line(row, &mut out);
    }
    out.push('\n');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_fast_fn() {
        let b = Bench {
            warmup: Duration::from_millis(5),
            budget: Duration::from_millis(30),
            min_samples: 5,
            max_samples: 50,
        };
        let st = b.measure("noop", || 1 + 1);
        assert!(st.samples >= 5);
        assert!(st.median_ns >= 0.0);
    }

    #[test]
    fn journal_to_json_round_trips() {
        let mut j = BenchJournal::new();
        assert!(j.is_empty());
        let st = Stats {
            samples: 10,
            median_ns: 2_000.0,
            mean_ns: 2_100.0,
            p95_ns: 2_500.0,
            mad_ns: 50.0,
        };
        j.record("nid-t4", "bitslice/avx2", 256, 1024, &st);
        j.record("jsc-m-lite", "bitslice/scalar", 64, 512, &st);
        assert_eq!(j.len(), 2);
        // Serialize and re-parse through the crate's own JSON layer so the
        // emitted document is pinned to be well-formed.
        let doc = Json::parse(&j.to_json().to_string_pretty()).expect("well-formed journal");
        let root = doc.as_obj().expect("object root");
        assert_eq!(root.get("schema").unwrap().as_str().unwrap(), "polylut-bench-v1");
        let recs = root.get("records").unwrap().as_arr().expect("records array");
        assert_eq!(recs.len(), 2);
        let r0 = recs[0].as_obj().unwrap();
        assert_eq!(r0.get("geometry").unwrap().as_str().unwrap(), "nid-t4");
        assert_eq!(r0.get("lanes").unwrap().as_usize().unwrap(), 256);
        // 1024 samples at 2 µs/call = 512e6 samples/s.
        let sps = r0.get("samples_per_sec").unwrap().as_f64().unwrap();
        assert!((sps - 512e6).abs() < 1.0, "{sps}");
    }

    #[test]
    fn closed_loop_counts_every_outcome_exactly_once() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let calls = AtomicUsize::new(0);
        let rep = closed_loop_load(3, 8, |i| {
            calls.fetch_add(1, Ordering::Relaxed);
            match i % 3 {
                0 => LoadOutcome::Ok,
                1 => LoadOutcome::Shed,
                _ => LoadOutcome::Error,
            }
        });
        assert_eq!(calls.load(Ordering::Relaxed), 24);
        assert_eq!(rep.sent, 24);
        assert_eq!((rep.ok, rep.shed, rep.errors), (8, 8, 8));
        assert_eq!(rep.mode, "closed");
        assert!(rep.throughput_rps > 0.0);
        assert!(rep.line().contains("req/s"), "{}", rep.line());
    }

    #[test]
    fn open_loop_services_every_scheduled_arrival() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let calls = AtomicUsize::new(0);
        // High rate + tiny total: the pacer finishes near-instantly and
        // the run is bounded by service, so no timing assertions needed.
        let rep = open_loop_load(1e6, 40, 4, |_| {
            calls.fetch_add(1, Ordering::Relaxed);
            LoadOutcome::Ok
        });
        assert_eq!(calls.load(Ordering::Relaxed), 40, "each arrival serviced once");
        assert_eq!(rep.sent, 40);
        assert_eq!(rep.ok, 40);
        assert_eq!((rep.shed, rep.errors), (0, 0));
        assert_eq!(rep.mode, "open");
        assert!(rep.p99_us >= rep.p50_us);
    }

    #[test]
    fn serve_records_share_the_journal_schema() {
        let mut j = BenchJournal::new();
        j.record_serve(ServeRecord {
            geometry: "nid-t4".into(),
            mode: "open".into(),
            replicas: 2,
            target_batch: 64,
            deadline_us: 200,
            offered_rps: 5_000.0,
            clients: 4,
            requests: 1_000,
            ok: 990,
            shed: 10,
            throughput_rps: 4_800.0,
            p50_us: 120.0,
            p99_us: 900.0,
        });
        assert_eq!(j.len(), 1);
        assert!(!j.is_empty());
        let doc = Json::parse(&j.to_json().to_string_pretty()).expect("well-formed journal");
        let root = doc.as_obj().expect("object root");
        assert_eq!(root.get("schema").unwrap().as_str().unwrap(), "polylut-bench-v1");
        let recs = root.get("records").unwrap().as_arr().expect("records array");
        let r0 = recs[0].as_obj().unwrap();
        assert_eq!(r0.get("mode").unwrap().as_str().unwrap(), "open");
        assert_eq!(r0.get("replicas").unwrap().as_usize().unwrap(), 2);
        assert_eq!(r0.get("deadline_us").unwrap().as_usize().unwrap(), 200);
        assert_eq!(r0.get("shed").unwrap().as_usize().unwrap(), 10);
        assert!(r0.get("p99_us").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn netlist_records_share_the_journal_schema() {
        let mut j = BenchJournal::new();
        j.record_netlist(NetlistRecord {
            geometry: "nid-t4".into(),
            level: "fold+dc".into(),
            ops_before: 120,
            ops_after: 90,
            samples_per_sec: 1e6,
            median_ns: 64_000.0,
        });
        assert_eq!(j.len(), 1);
        assert!(!j.is_empty());
        let doc = Json::parse(&j.to_json().to_string_pretty()).expect("well-formed journal");
        let root = doc.as_obj().expect("object root");
        assert_eq!(root.get("schema").unwrap().as_str().unwrap(), "polylut-bench-v1");
        let recs = root.get("records").unwrap().as_arr().expect("records array");
        let r0 = recs[0].as_obj().unwrap();
        assert_eq!(r0.get("level").unwrap().as_str().unwrap(), "fold+dc");
        assert_eq!(r0.get("ops_before").unwrap().as_usize().unwrap(), 120);
        assert_eq!(r0.get("ops_after").unwrap().as_usize().unwrap(), 90);
        assert!(r0.get("samples_per_sec").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }
}
