//! Mini benchmark harness (criterion is not vendored).
//!
//! `cargo bench` targets in this repo are `harness = false` binaries built
//! on this module.  `Bench::measure` warms up, then collects wall-clock
//! samples until a time budget or sample count is reached and reports
//! median / mean / p95 with a simple MAD-based spread, in criterion-like
//! one-line format.  `table` renders paper-style rows (used by the
//! fig6/table2/table3/table5 benches).

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct Stats {
    pub samples: usize,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub p95_ns: f64,
    pub mad_ns: f64,
}

impl Stats {
    pub fn throughput(&self, items: f64) -> f64 {
        items / (self.median_ns * 1e-9)
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

pub struct Bench {
    pub warmup: Duration,
    pub budget: Duration,
    pub min_samples: usize,
    pub max_samples: usize,
}

impl Default for Bench {
    fn default() -> Self {
        // POLYLUT_BENCH_QUICK=1 trims budgets for CI-style smoke runs.
        let quick = std::env::var("POLYLUT_BENCH_QUICK").is_ok();
        Self {
            warmup: Duration::from_millis(if quick { 50 } else { 300 }),
            budget: Duration::from_secs(if quick { 1 } else { 3 }),
            min_samples: 10,
            max_samples: if quick { 100 } else { 1000 },
        }
    }
}

impl Bench {
    /// Measure `f`, print a criterion-like line, return stats.
    pub fn measure<R>(&self, name: &str, mut f: impl FnMut() -> R) -> Stats {
        // Warmup.
        let t0 = Instant::now();
        while t0.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // Sample.
        let mut samples_ns: Vec<f64> = Vec::new();
        let t1 = Instant::now();
        while (t1.elapsed() < self.budget || samples_ns.len() < self.min_samples)
            && samples_ns.len() < self.max_samples
        {
            let s = Instant::now();
            std::hint::black_box(f());
            samples_ns.push(s.elapsed().as_nanos() as f64);
        }
        samples_ns.sort_by(f64::total_cmp);
        let n = samples_ns.len();
        let median = samples_ns[n / 2];
        let mean = samples_ns.iter().sum::<f64>() / n as f64;
        let p95 = samples_ns[(n as f64 * 0.95) as usize % n];
        let mut devs: Vec<f64> = samples_ns.iter().map(|x| (x - median).abs()).collect();
        devs.sort_by(f64::total_cmp);
        let mad = devs[n / 2];
        let st = Stats { samples: n, median_ns: median, mean_ns: mean, p95_ns: p95, mad_ns: mad };
        println!(
            "{name:<48} time: [{} ± {}]  p95: {}  ({} samples)",
            fmt_ns(st.median_ns),
            fmt_ns(st.mad_ns),
            fmt_ns(st.p95_ns),
            st.samples
        );
        st
    }
}

/// Render an aligned text table (paper-style rows) to stdout.
pub fn table(title: &str, headers: &[&str], rows: &[Vec<String>]) {
    println!("\n=== {title} ===");
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let line = |cells: &[String]| {
        let mut s = String::new();
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!("{:<w$}  ", c, w = widths.get(i).copied().unwrap_or(8)));
        }
        println!("{}", s.trim_end());
    };
    line(&headers.iter().map(|h| h.to_string()).collect::<Vec<_>>());
    line(&widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>());
    for row in rows {
        line(row);
    }
    println!();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_fast_fn() {
        let b = Bench {
            warmup: Duration::from_millis(5),
            budget: Duration::from_millis(30),
            min_samples: 5,
            max_samples: 50,
        };
        let st = b.measure("noop", || 1 + 1);
        assert!(st.samples >= 5);
        assert!(st.median_ns >= 0.0);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }
}
