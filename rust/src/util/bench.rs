//! Mini benchmark harness (criterion is not vendored).
//!
//! `cargo bench` targets in this repo are `harness = false` binaries built
//! on this module.  `Bench::measure` warms up, then collects wall-clock
//! samples until a time budget or sample count is reached and reports
//! median / mean / p95 with a simple MAD-based spread, in criterion-like
//! one-line format.  `table` renders paper-style rows (used by the
//! fig6/table2/table3/table5 benches).  [`BenchJournal`] accumulates
//! machine-readable records and, when `POLYLUT_BENCH_JSON=<path>` is set,
//! writes them as a JSON document (the micro_hotpaths bench uses it to
//! emit `BENCH_bitslice.json` for the CI bench-smoke leg).

use std::time::{Duration, Instant};

use crate::util::json::{Json, JsonObj};

#[derive(Debug, Clone)]
pub struct Stats {
    pub samples: usize,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub p95_ns: f64,
    pub mad_ns: f64,
}

impl Stats {
    pub fn throughput(&self, items: f64) -> f64 {
        items / (self.median_ns * 1e-9)
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

pub struct Bench {
    pub warmup: Duration,
    pub budget: Duration,
    pub min_samples: usize,
    pub max_samples: usize,
}

impl Default for Bench {
    fn default() -> Self {
        // POLYLUT_BENCH_QUICK=1 trims budgets for CI-style smoke runs.
        let quick = std::env::var("POLYLUT_BENCH_QUICK").is_ok();
        Self {
            warmup: Duration::from_millis(if quick { 50 } else { 300 }),
            budget: Duration::from_secs(if quick { 1 } else { 3 }),
            min_samples: 10,
            max_samples: if quick { 100 } else { 1000 },
        }
    }
}

impl Bench {
    /// Measure `f`, print a criterion-like line, return stats.
    pub fn measure<R>(&self, name: &str, mut f: impl FnMut() -> R) -> Stats {
        // Warmup.
        let t0 = Instant::now();
        while t0.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // Sample.
        let mut samples_ns: Vec<f64> = Vec::new();
        let t1 = Instant::now();
        while (t1.elapsed() < self.budget || samples_ns.len() < self.min_samples)
            && samples_ns.len() < self.max_samples
        {
            let s = Instant::now();
            std::hint::black_box(f());
            samples_ns.push(s.elapsed().as_nanos() as f64);
        }
        samples_ns.sort_by(f64::total_cmp);
        let n = samples_ns.len();
        let median = samples_ns[n / 2];
        let mean = samples_ns.iter().sum::<f64>() / n as f64;
        let p95 = samples_ns[(n as f64 * 0.95) as usize % n];
        let mut devs: Vec<f64> = samples_ns.iter().map(|x| (x - median).abs()).collect();
        devs.sort_by(f64::total_cmp);
        let mad = devs[n / 2];
        let st = Stats { samples: n, median_ns: median, mean_ns: mean, p95_ns: p95, mad_ns: mad };
        println!(
            "{name:<48} time: [{} ± {}]  p95: {}  ({} samples)",
            fmt_ns(st.median_ns),
            fmt_ns(st.mad_ns),
            fmt_ns(st.p95_ns),
            st.samples
        );
        st
    }
}

/// Environment variable naming the file [`BenchJournal::write_if_requested`]
/// writes (unset = no file is written).
pub const BENCH_JSON_ENV: &str = "POLYLUT_BENCH_JSON";

/// One machine-readable throughput record: a (geometry, engine, lane-width)
/// point with its samples-per-second figure derived from [`Stats`].
#[derive(Debug, Clone)]
pub struct BenchRecord {
    /// Model geometry the measurement ran on (e.g. `"nid-t4"`).
    pub geometry: String,
    /// Engine / kernel path label (e.g. `"bitslice/avx2"`, `"plan"`).
    pub engine: String,
    /// Active lane width (samples per op-stream walk; 0 = not lane-based).
    pub lanes: usize,
    /// Batch size the throughput figure is normalized over.
    pub batch: usize,
    /// Samples retired per second at the median time.
    pub samples_per_sec: f64,
    /// Median wall-clock time per measured call, nanoseconds.
    pub median_ns: f64,
}

/// Accumulator for [`BenchRecord`]s with a JSON emitter, env-gated via
/// [`BENCH_JSON_ENV`] so normal bench runs stay file-free.
#[derive(Debug, Default)]
pub struct BenchJournal {
    records: Vec<BenchRecord>,
}

impl BenchJournal {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one throughput point; `batch` is the items-per-call figure
    /// fed to [`Stats::throughput`].
    pub fn record(&mut self, geometry: &str, engine: &str, lanes: usize, batch: usize, st: &Stats) {
        self.records.push(BenchRecord {
            geometry: geometry.to_string(),
            engine: engine.to_string(),
            lanes,
            batch,
            samples_per_sec: st.throughput(batch as f64),
            median_ns: st.median_ns,
        });
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// The journal as a JSON document:
    /// `{"schema": "polylut-bench-v1", "records": [{...}, ...]}`.
    pub fn to_json(&self) -> Json {
        let mut root = JsonObj::new();
        root.insert("schema", "polylut-bench-v1");
        let records: Vec<Json> = self
            .records
            .iter()
            .map(|r| {
                let mut o = JsonObj::new();
                o.insert("geometry", r.geometry.as_str());
                o.insert("engine", r.engine.as_str());
                o.insert("lanes", r.lanes);
                o.insert("batch", r.batch);
                o.insert("samples_per_sec", r.samples_per_sec);
                o.insert("median_ns", r.median_ns);
                Json::Obj(o)
            })
            .collect();
        root.insert("records", Json::Arr(records));
        Json::Obj(root)
    }

    /// Write the journal to the path named by [`BENCH_JSON_ENV`], if set.
    /// Returns the path written to, `None` when the env var is unset or
    /// empty.  IO failures are reported, not fatal — a bench run should
    /// still print its numbers when the journal path is unwritable.
    pub fn write_if_requested(&self) -> Option<std::path::PathBuf> {
        let path = match std::env::var(BENCH_JSON_ENV) {
            Ok(p) if !p.is_empty() => std::path::PathBuf::from(p),
            _ => return None,
        };
        let text = self.to_json().to_string_pretty();
        match std::fs::write(&path, text) {
            Ok(()) => {
                println!("[bench] wrote {} records to {}", self.records.len(), path.display());
                Some(path)
            }
            Err(e) => {
                eprintln!("[bench] could not write {}: {e}", path.display());
                None
            }
        }
    }
}

/// Render an aligned text table (paper-style rows) to stdout.
pub fn table(title: &str, headers: &[&str], rows: &[Vec<String>]) {
    println!("\n=== {title} ===");
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let line = |cells: &[String]| {
        let mut s = String::new();
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!("{:<w$}  ", c, w = widths.get(i).copied().unwrap_or(8)));
        }
        println!("{}", s.trim_end());
    };
    line(&headers.iter().map(|h| h.to_string()).collect::<Vec<_>>());
    line(&widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>());
    for row in rows {
        line(row);
    }
    println!();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_fast_fn() {
        let b = Bench {
            warmup: Duration::from_millis(5),
            budget: Duration::from_millis(30),
            min_samples: 5,
            max_samples: 50,
        };
        let st = b.measure("noop", || 1 + 1);
        assert!(st.samples >= 5);
        assert!(st.median_ns >= 0.0);
    }

    #[test]
    fn journal_to_json_round_trips() {
        let mut j = BenchJournal::new();
        assert!(j.is_empty());
        let st = Stats {
            samples: 10,
            median_ns: 2_000.0,
            mean_ns: 2_100.0,
            p95_ns: 2_500.0,
            mad_ns: 50.0,
        };
        j.record("nid-t4", "bitslice/avx2", 256, 1024, &st);
        j.record("jsc-m-lite", "bitslice/scalar", 64, 512, &st);
        assert_eq!(j.len(), 2);
        // Serialize and re-parse through the crate's own JSON layer so the
        // emitted document is pinned to be well-formed.
        let doc = Json::parse(&j.to_json().to_string_pretty()).expect("well-formed journal");
        let root = doc.as_obj().expect("object root");
        assert_eq!(root.get("schema").unwrap().as_str().unwrap(), "polylut-bench-v1");
        let recs = root.get("records").unwrap().as_arr().expect("records array");
        assert_eq!(recs.len(), 2);
        let r0 = recs[0].as_obj().unwrap();
        assert_eq!(r0.get("geometry").unwrap().as_str().unwrap(), "nid-t4");
        assert_eq!(r0.get("lanes").unwrap().as_usize().unwrap(), 256);
        // 1024 samples at 2 µs/call = 512e6 samples/s.
        let sps = r0.get("samples_per_sec").unwrap().as_f64().unwrap();
        assert!((sps - 512e6).abs() < 1.0, "{sps}");
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }
}
