//! Data-parallel helpers over std threads (tokio/rayon are not vendored).
//!
//! The LUT compiler and the benchmark harness are embarrassingly parallel
//! over neurons/configs; `parallel_map` fans a slice out over a bounded set
//! of scoped worker threads with dynamic (chunk-stealing) scheduling.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use (1..=available_parallelism).
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Apply `f` to every element index of `items`, in parallel, preserving
/// output order. `f` must be Sync; items are read-shared.
pub fn parallel_map<T: Sync, R: Send>(
    items: &[T],
    workers: usize,
    f: impl Fn(usize, &T) -> R + Sync,
) -> Vec<R> {
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    if workers == 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let next = AtomicUsize::new(0);
    let out: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(i, &items[i]);
                // Each slot is written by exactly one worker; a poisoned
                // mutex here means `f` panicked, which the scope re-raises.
                *out[i].lock().unwrap_or_else(|p| p.into_inner()) = Some(r);
            });
        }
    });
    out.into_iter()
        .map(|m| {
            m.into_inner()
                .unwrap_or_else(|p| p.into_inner())
                .expect("parallel_map: every index claimed exactly once")
        })
        .collect()
}

/// Parallel for over a range with dynamic scheduling; `f(i)` for i in 0..n.
pub fn parallel_for(n: usize, workers: usize, f: impl Fn(usize) + Sync) {
    let workers = workers.clamp(1, n.max(1));
    if n == 0 {
        return;
    }
    if workers == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_preserves_order() {
        let items: Vec<u64> = (0..1000).collect();
        let out = parallel_map(&items, 8, |_, &x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn map_single_worker() {
        let items = vec![1, 2, 3];
        assert_eq!(parallel_map(&items, 1, |i, &x| x + i), vec![1, 3, 5]);
    }

    #[test]
    fn for_covers_all() {
        use std::sync::atomic::AtomicU64;
        let sum = AtomicU64::new(0);
        parallel_for(100, 4, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 4950);
    }

    #[test]
    fn empty_inputs() {
        let items: Vec<u8> = vec![];
        assert!(parallel_map(&items, 4, |_, &x| x).is_empty());
        parallel_for(0, 4, |_| panic!("must not run"));
    }
}
