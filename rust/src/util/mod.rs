//! Substrate utilities built from scratch — the deployment image vendors no
//! serde/clap/tokio/criterion/proptest/rand, so this repo carries its own
//! minimal, tested equivalents.

pub mod bench;
pub mod cli;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
