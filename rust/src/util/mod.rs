//! Substrate utilities built from scratch — the deployment image vendors no
//! serde/clap/tokio/criterion/proptest/rand, so this repo carries its own
//! minimal, tested equivalents.

pub mod bench;
pub mod cli;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;

/// NaN-safe argmax over f32 logits (total order: NaN sorts above +inf, so a
/// NaN logit can never panic the serving path the way
/// `partial_cmp().unwrap()` did).  Returns 0 for an empty slice.
pub fn argmax_f32(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax_f32(&[0.1, 3.0, -2.0]), 1);
        assert_eq!(argmax_f32(&[-1.0]), 0);
        assert_eq!(argmax_f32(&[]), 0);
    }

    #[test]
    fn argmax_does_not_panic_on_nan() {
        // total_cmp puts NaN above every number — deterministic, no panic.
        assert_eq!(argmax_f32(&[1.0, f32::NAN, 2.0]), 1);
        assert_eq!(argmax_f32(&[f32::NAN, f32::NAN]), 1);
        assert_eq!(argmax_f32(&[1.0, 2.0, f32::NEG_INFINITY]), 1);
    }
}
