//! Tiny CLI argument parser (clap is not vendored).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional arguments;
//! the `polylut` binary and every example/bench use it, so invocations look
//! like any other production CLI.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse argv (without the program name). `flag_names` lists options that
    /// take no value; everything else starting with `--` consumes one.
    pub fn parse(argv: &[String], flag_names: &[&str]) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if rest.is_empty() {
                    // `--` terminates option parsing.
                    out.positional.extend(it.cloned());
                    break;
                }
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&rest) {
                    out.flags.push(rest.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| anyhow!("option --{rest} expects a value"))?;
                    out.options.insert(rest.to_string(), v.clone());
                }
            } else {
                out.positional.push(a.clone());
            }
        }
        Ok(out)
    }

    pub fn from_env(flag_names: &[&str]) -> Result<Args> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Self::parse(&argv, flag_names)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name}: bad integer {v:?}")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name}: bad float {v:?}")),
        }
    }

    pub fn require(&self, name: &str) -> Result<&str> {
        self.get(name).ok_or_else(|| anyhow!("missing required option --{name}"))
    }

    /// Value of `--name` constrained to one of `allowed` (typo guard for
    /// enumerated options like `--backend lut|pjrt`); `default` when absent.
    pub fn get_choice<'a>(
        &'a self,
        name: &str,
        default: &'a str,
        allowed: &[&str],
    ) -> Result<&'a str> {
        debug_assert!(allowed.contains(&default));
        let v = self.get_or(name, default);
        if allowed.contains(&v) {
            Ok(v)
        } else {
            bail!("--{name}: {v:?} is not one of {}", allowed.join("|"))
        }
    }

    /// Error if any option outside `known` was supplied (typo guard).
    pub fn check_known(&self, known: &[&str]) -> Result<()> {
        for k in self.options.keys() {
            if !known.contains(&k.as_str()) {
                bail!("unknown option --{k} (known: {})", known.join(", "));
            }
        }
        for f in &self.flags {
            if !known.contains(&f.as_str()) {
                bail!("unknown flag --{f}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn mixed_args() {
        let a = Args::parse(
            &argv(&["serve", "--port", "8080", "--verbose", "--mode=fast", "extra"]),
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.positional, vec!["serve", "extra"]);
        assert_eq!(a.get("port"), Some("8080"));
        assert_eq!(a.get("mode"), Some("fast"));
        assert!(a.flag("verbose"));
        assert_eq!(a.get_usize("port", 0).unwrap(), 8080);
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(&argv(&["--port"]), &[]).is_err());
    }

    #[test]
    fn double_dash_stops_parsing() {
        let a = Args::parse(&argv(&["--x", "1", "--", "--not-an-option"]), &[]).unwrap();
        assert_eq!(a.positional, vec!["--not-an-option"]);
    }

    #[test]
    fn choice_options() {
        let a = Args::parse(&argv(&["--backend", "pjrt"]), &[]).unwrap();
        assert_eq!(a.get_choice("backend", "lut", &["lut", "pjrt"]).unwrap(), "pjrt");
        assert_eq!(a.get_choice("mode", "fast", &["fast", "slow"]).unwrap(), "fast");
        let bad = Args::parse(&argv(&["--backend", "gpu"]), &[]).unwrap();
        assert!(bad.get_choice("backend", "lut", &["lut", "pjrt"]).is_err());
    }

    #[test]
    fn unknown_option_guard() {
        let a = Args::parse(&argv(&["--prot", "1"]), &[]).unwrap();
        assert!(a.check_known(&["port"]).is_err());
        assert!(a.check_known(&["prot"]).is_ok());
    }
}
