//! Deterministic PRNG substrate (the `rand` crate is not vendored).
//!
//! `Rng` is SplitMix64 — tiny state, excellent 64-bit avalanche, and
//! reproducible across platforms; every workload generator, connectivity
//! sampler and property test in this repo derives its stream from an
//! explicit seed so runs are bit-reproducible.

/// SplitMix64 PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    /// Derive an independent stream (for per-worker / per-case seeding).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24BAED4963EE407))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). n must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free-enough reduction; bias is
        // negligible for the n (< 2^32) used here, but reject to be exact.
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = (((x as u128 * n as u128) >> 64) as u64, (x as u128 * n as u128) as u64);
            if lo >= n.wrapping_neg() % n {
                return hi as usize;
            }
        }
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal (Box–Muller; one value per call, simple and exact).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = self.f64();
            if u > 1e-300 {
                let v = self.f64();
                return (-2.0 * u.ln()).sqrt() * (std::f64::consts::TAU * v).cos();
            }
        }
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// k distinct values from [0, n), uniform (partial Fisher–Yates).
    pub fn choose_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "choose_distinct: k={k} > n={n}");
        // For small k relative to n use rejection into a set; else shuffle.
        if k * 4 <= n {
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let v = self.below(n);
                if !out.contains(&v) {
                    out.push(v);
                }
            }
            out
        } else {
            let mut pool: Vec<usize> = (0..n).collect();
            for i in 0..k {
                let j = i + self.below(n - i);
                pool.swap(i, j);
            }
            pool.truncate(k);
            pool
        }
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut t = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(1);
        let n = 20000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn choose_distinct_properties() {
        let mut r = Rng::new(3);
        for &(n, k) in &[(10, 3), (10, 10), (100, 7), (5, 0)] {
            let v = r.choose_distinct(n, k);
            assert_eq!(v.len(), k);
            let mut s = v.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), k, "duplicates for n={n} k={k}");
            assert!(v.iter().all(|&x| x < n));
        }
    }

    #[test]
    fn f32_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }
}
