//! Minimal JSON parser/serializer (serde is not vendored in this image).
//!
//! Supports the full JSON grammar (RFC 8259): objects, arrays, strings with
//! escapes, numbers, booleans, null.  Numbers are held as `f64`; the
//! artifact manifests this repo exchanges (meta.json, weights.json, reports)
//! only need f64/i64 precision.  Key order of objects is preserved so
//! emitted reports diff cleanly.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

/// A JSON value. Objects keep insertion order via a Vec of pairs plus a
/// lazily-consulted index (lookups are O(log n) through the index).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(JsonObj),
}

/// Order-preserving JSON object.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct JsonObj {
    pairs: Vec<(String, Json)>,
    index: BTreeMap<String, usize>,
}

impl JsonObj {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, key: impl Into<String>, val: impl Into<Json>) {
        let key = key.into();
        if let Some(&i) = self.index.get(&key) {
            self.pairs[i].1 = val.into();
        } else {
            self.index.insert(key.clone(), self.pairs.len());
            self.pairs.push((key, val.into()));
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.index.get(key).map(|&i| &self.pairs[i].1)
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, &Json)> {
        self.pairs.iter().map(|(k, v)| (k.as_str(), v))
    }

    pub fn len(&self) -> usize {
        self.pairs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pairs.is_empty()
    }
}

impl Json {
    // ---- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            other => bail!("expected number, got {}", other.kind()),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        let x = self.as_f64()?;
        if x.fract() != 0.0 || x.abs() > 2f64.powi(53) {
            bail!("expected integer, got {x}");
        }
        Ok(x as i64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_i64()?;
        usize::try_from(x).map_err(|_| anyhow!("expected usize, got {x}"))
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => bail!("expected string, got {}", other.kind()),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => bail!("expected bool, got {}", other.kind()),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            other => bail!("expected array, got {}", other.kind()),
        }
    }

    pub fn as_obj(&self) -> Result<&JsonObj> {
        match self {
            Json::Obj(o) => Ok(o),
            other => bail!("expected object, got {}", other.kind()),
        }
    }

    /// Object field access with a contextual error.
    pub fn field(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| anyhow!("missing field {key:?}"))
    }

    /// Array of numbers -> Vec<f32>.
    pub fn f32_vec(&self) -> Result<Vec<f32>> {
        self.as_arr()?.iter().map(|v| Ok(v.as_f64()? as f32)).collect()
    }

    /// Array of numbers -> Vec<usize>.
    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    fn kind(&self) -> &'static str {
        match self {
            Json::Null => "null",
            Json::Bool(_) => "bool",
            Json::Num(_) => "number",
            Json::Str(_) => "string",
            Json::Arr(_) => "array",
            Json::Obj(_) => "object",
        }
    }

    // ---- parse -----------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.skip_ws();
        let v = p.value().context("JSON parse error")?;
        p.skip_ws();
        if p.i != bytes.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    // ---- serialize ---------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.fract() == 0.0 && x.abs() < 2f64.powi(53) {
        let _ = write!(out, "{}", x as i64);
    } else {
        // Shortest f64 round-trip repr (rust's Display for f64 is shortest).
        let _ = write!(out, "{x}");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---- conversions ---------------------------------------------------------

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<f32> for Json {
    fn from(x: f32) -> Self {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Self {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Self {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Self {
        Json::Str(x)
    }
}
impl From<JsonObj> for Json {
    fn from(x: JsonObj) -> Self {
        Json::Obj(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

// ---- parser ----------------------------------------------------------------

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected byte {:?} at {}", c as char, self.i),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().with_context(|| format!("bad number {s:?}"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| anyhow!("truncated \\u escape"))?;
                            let cp = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            self.i += 4;
                            // Surrogate pairs: join if a low surrogate follows.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    let hex2 = self
                                        .b
                                        .get(self.i + 2..self.i + 6)
                                        .ok_or_else(|| anyhow!("truncated surrogate"))?;
                                    let lo = u32::from_str_radix(std::str::from_utf8(hex2)?, 16)?;
                                    self.i += 6;
                                    let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(ch.ok_or_else(|| anyhow!("bad \\u escape"))?);
                        }
                        e => bail!("bad escape \\{}", e as char),
                    }
                }
                c => {
                    // Re-sync on UTF-8 multibyte: step back and take the char.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        self.i -= 1;
                        let rest = std::str::from_utf8(&self.b[self.i..])?;
                        let ch = rest
                            .chars()
                            .next()
                            .expect("rest starts at a non-ASCII byte, so it is non-empty");
                        s.push(ch);
                        self.i += ch.len_utf8();
                    }
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected , or ] got {:?} at {}", c as char, self.i),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut o = JsonObj::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(o));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            o.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(o));
                }
                c => bail!("expected , or }} got {:?} at {}", c as char, self.i),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": "hi\nthere", "c": true, "d": null}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.field("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.field("b").unwrap().as_str().unwrap(), "hi\nthere");
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn nested_arrays() {
        let v = Json::parse("[[1,2],[3,4],[]]").unwrap();
        let arr = v.as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[1].usize_vec().unwrap(), vec![3, 4]);
    }

    #[test]
    fn escapes_and_unicode() {
        let v = Json::parse(r#""é€ x 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é€ x 😀");
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn object_order_preserved() {
        let v = Json::parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<_> = v.as_obj().unwrap().iter().map(|(k, _)| k).collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("01x").is_err());
        assert!(Json::parse("\"abc").is_err());
        assert!(Json::parse("[1] junk").is_err());
    }

    #[test]
    fn float_roundtrip_precision() {
        let v = Json::Num(0.1234567890123457);
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn duplicate_keys_last_wins() {
        let v = Json::parse(r#"{"a":1,"a":2}"#).unwrap();
        assert_eq!(v.field("a").unwrap().as_i64().unwrap(), 2);
        assert_eq!(v.as_obj().unwrap().len(), 1);
    }
}
