//! Serve-path load tests: the replica fleet under closed- and open-loop
//! traffic (ARCHITECTURE.md §9), sweeping replica count × batch deadline
//! and emitting the `BENCH_serve.json` trajectory.
//!
//!   cargo bench --bench serve_load
//!
//! POLYLUT_BENCH_QUICK=1 trims request counts for the CI load-test leg;
//! POLYLUT_BENCH_JSON=<path> writes the machine-readable records.  Every
//! sampled response is asserted bit-exact against the plan engine, so the
//! sweep doubles as an end-to-end correctness pass over the fleet.

// Benches are a separate crate: clippy's allow-unwrap-in-tests doesn't
// reach them, so the workspace unwrap_used deny is lifted per-file.
#![allow(clippy::unwrap_used)]

use std::sync::Arc;
use std::time::Duration;

use polylut_add::coordinator::fleet::{Fleet, FleetConfig, FleetError};
use polylut_add::coordinator::FrozenModel;
use polylut_add::nn::config;
use polylut_add::nn::network::Network;
use polylut_add::sim::EngineSelect;
use polylut_add::util::bench::{
    closed_loop_load, open_loop_load, BenchJournal, LoadOutcome, LoadReport, ServeRecord,
};
use polylut_add::util::pool::default_workers;
use polylut_add::util::rng::Rng;

fn serve_record(
    rep: &LoadReport,
    replicas: usize,
    target_batch: usize,
    deadline_us: u64,
    offered_rps: f64,
    clients: usize,
) -> ServeRecord {
    ServeRecord {
        geometry: "nid-t4".into(),
        mode: rep.mode.into(),
        replicas,
        target_batch,
        deadline_us,
        offered_rps,
        clients,
        requests: rep.sent,
        ok: rep.ok,
        shed: rep.shed,
        throughput_rps: rep.throughput_rps,
        p50_us: rep.p50_us,
        p99_us: rep.p99_us,
    }
}

fn main() {
    let quick = std::env::var("POLYLUT_BENCH_QUICK").is_ok();
    // The paper's Table IV Add2 geometry (random weights — serve-path
    // timing and bit-exactness do not depend on training).
    let cfg = config::nid_add2();
    let net = Network::random(&cfg, &mut Rng::new(0x5EED));
    let n_classes = cfg.n_classes;
    let model = Arc::new(FrozenModel::from_network(net, default_workers()));
    let lanes = model.bitslice.lanes();

    // Request pool with expected logits precomputed once via the plan
    // engine — the oracle every sampled fleet response is checked against.
    let sim = model.sim();
    let mut rng = Rng::new(77);
    let pool: Vec<Vec<f32>> =
        (0..256).map(|_| (0..cfg.widths[0]).map(|_| rng.f32()).collect()).collect();
    let expected: Vec<Vec<f32>> = pool.iter().map(|x| sim.forward(x)).collect();

    let mut journal = BenchJournal::new();
    let clients = 8usize;
    let per_client = if quick { 50 } else { 400 };
    let open_total = if quick { 400 } else { 4_000 };

    println!(
        "[serve] nid-t4 replica-fleet load sweep: lanes={lanes}, \
         replicas x deadline grid, {clients} clients"
    );
    for &replicas in &[1usize, 2] {
        for &deadline_us in &[100u64, 1_000] {
            let fleet = Fleet::start(
                model.clone(),
                default_workers(),
                EngineSelect::auto_for_lanes(lanes),
                n_classes,
                FleetConfig {
                    replicas,
                    target_batch: 0, // pack toward the active lane width
                    batch_deadline: Duration::from_micros(deadline_us),
                    queue_depth: 4_096,
                    shed_after: None,
                },
            );
            let client = fleet.client();
            let run = |i: usize| {
                let k = i % pool.len();
                match client.infer(pool[k].clone()) {
                    Ok(resp) => {
                        assert_eq!(
                            resp.logits, expected[k],
                            "fleet response must be bit-exact vs the plan engine"
                        );
                        LoadOutcome::Ok
                    }
                    Err(FleetError::Shed { .. } | FleetError::QueueFull { .. }) => {
                        LoadOutcome::Shed
                    }
                    Err(e) => {
                        eprintln!("[serve] request failed: {e}");
                        LoadOutcome::Error
                    }
                }
            };
            let closed = closed_loop_load(clients, per_client, &run);
            println!("[serve] replicas={replicas} deadline={deadline_us}µs {}", closed.line());
            journal.record_serve(serve_record(
                &closed,
                replicas,
                lanes,
                deadline_us,
                0.0,
                clients,
            ));
            // Offer ~60% of the measured closed-loop capacity: the open
            // loop probes queueing latency under real load without being
            // pinned into permanent overload on a slow host.
            let offered = (closed.throughput_rps * 0.6).max(500.0);
            let open = open_loop_load(offered, open_total, clients, &run);
            println!("[serve] replicas={replicas} deadline={deadline_us}µs {}", open.line());
            journal.record_serve(serve_record(
                &open,
                replicas,
                lanes,
                deadline_us,
                offered,
                clients,
            ));
            println!("  {}", fleet.metrics.snapshot());
            assert_eq!(
                closed.errors + open.errors,
                0,
                "in-process fleet must not produce replica errors"
            );
            fleet.shutdown();
        }
    }

    // Machine-readable serve records (BENCH_serve.json in CI) — written
    // only when POLYLUT_BENCH_JSON names a path.
    journal.write_if_requested();
}
