//! Table II — accuracy and hardware results, PolyLUT vs PolyLUT-Add at
//! iso-(D, F): lookup-table words, LUT, FF, F_max, latency cycles and
//! table-generation ("RTL Gen.") time.
//!
//!   cargo bench --bench table2_hw
//!
//! Shape expectations from the paper: A=2 improves accuracy and costs ~2-3×
//! LUTs at the same (D, F); exhaustively widening PolyLUT's fan-in instead
//! would multiply table words by 256-1024× (reported analytically below,
//! as in the paper's `-` rows which exceeded their FPGA's memory).

use polylut_add::fpga::Strategy;
use polylut_add::harness;
use polylut_add::runtime::Engine;
use polylut_add::util::bench::table;

fn rows_for(
    engine: &Engine,
    model: &str,
    degrees: &[u32],
    adds: &[usize],
    wide_fan_bits: u32,
    rows: &mut Vec<Vec<String>>,
) {
    for &d in degrees {
        for &a in adds {
            let id = format!("{model}-d{d}-a{a}");
            let p = match harness::prepare(engine, &id) {
                Ok(p) => p,
                Err(e) => {
                    eprintln!("skip {id}: {e:#}");
                    continue;
                }
            };
            let r = harness::synth(&p, Strategy::Merged).expect("synth");
            rows.push(vec![
                model.to_string(),
                d.to_string(),
                if a == 1 { "PolyLUT".into() } else { format!("PolyLUT-Add x{a}") },
                format!("{}x{a}", p.man.config.fan[p.man.config.n_layers() - 1]),
                harness::pct(p.accuracy),
                p.man.config.table_words_total().to_string(),
                format!("{} ({:.2}%)", r.luts, r.lut_pct()),
                format!("{} ({:.2}%)", r.ffs, r.ff_pct()),
                format!("{:.0}", r.fmax_mhz),
                r.cycles.to_string(),
                format!("{:.1}s", r.gen_seconds),
            ]);
            // The paper's "increase F instead" comparison row (analytic —
            // exceeds memory in practice, exactly as the paper's dashes).
            if a == 1 {
                rows.push(vec![
                    model.to_string(),
                    d.to_string(),
                    "PolyLUT wide-F".into(),
                    "analytic".into(),
                    "-".into(),
                    format!(
                        "{} (x{})",
                        p.man.config.table_words_total() << wide_fan_bits,
                        1u64 << wide_fan_bits
                    ),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                ]);
            }
        }
    }
}

fn main() {
    let engine = Engine::cpu().expect("PJRT CPU client");
    let mut rows = Vec::new();
    // wide_fan_bits = beta * dF for the paper's bigger-F comparison:
    // HDR 10 vs 6 at beta=2 -> 8 bits (256x); JSC-XL 5 vs 3 at beta=5 -> 10
    // (1024x); JSC-M Lite 7 vs 4 at beta=3 -> 9 (512x); NID 8 vs 5 at
    // beta=3 -> 9 (512x).
    rows_for(&engine, "hdr", &[1, 2], &[1, 2, 3], 8, &mut rows);
    rows_for(&engine, "jsc-xl", &[1, 2], &[1, 2], 10, &mut rows);
    rows_for(&engine, "jsc-m-lite", &[1, 2], &[1, 2, 3], 9, &mut rows);
    rows_for(&engine, "nid-lite", &[1], &[1, 2], 9, &mut rows);
    table(
        "Table II — PolyLUT vs PolyLUT-Add (iso D,F; pipeline strategy 2; xcvu9p model)",
        &[
            "model", "D", "variant", "fan-in", "acc %", "table words", "LUT (util)",
            "FF (util)", "F_max MHz", "cycles", "gen time",
        ],
        &rows,
    );
}
