//! Table V — the two pipeline strategies on JSC-M Lite (the paper's case
//! study): F_max, latency cycles and latency ns for D ∈ {1,2}, A ∈ {2,3}.
//!
//!   cargo bench --bench table5_pipeline
//!
//! Shape expectation: strategy (1) keeps F_max high at 2x the cycles;
//! strategy (2) halves cycles and wins total latency at lower F_max.
//! Cycle counts are additionally validated by the cycle-accurate pipeline
//! simulator (not just the analytic model).  For each prepared model the
//! software twin's throughput is reported twice — naive per-sample LutSim
//! walk vs the compiled evaluation plan — as the plan-vs-naive comparison
//! point for this workload.
//!
//! Requires trained artifacts (`make artifacts`) and the native PJRT
//! runtime; skips cleanly without them.

use std::time::Instant;

use polylut_add::coordinator::FrozenModel;
use polylut_add::fpga::Strategy;
use polylut_add::harness;
use polylut_add::runtime::Engine;
use polylut_add::sim::{PipelineSim, Scratch};
use polylut_add::util::bench::table;

fn main() {
    let engine = match Engine::cpu() {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skip table5: PJRT unavailable ({e:#})");
            return;
        }
    };
    let mut rows = Vec::new();
    for d in [1u32, 2] {
        for a in [2usize, 3] {
            let id = format!("jsc-m-lite-d{d}-a{a}");
            let p = match harness::prepare(&engine, &id) {
                Ok(p) => p,
                Err(e) => {
                    eprintln!("skip {id}: {e:#}");
                    continue;
                }
            };
            let model = FrozenModel::from_network(p.net.clone(), 8);
            for (strategy, sname) in
                [(Strategy::SeparateRegisters, "(1)"), (Strategy::Merged, "(2)")]
            {
                let r = harness::synth(&p, strategy).expect("synth");
                // Validate the cycle count with the pipeline simulator.
                let inputs: Vec<Vec<i32>> = (0..32)
                    .map(|i| model.net.quantize_input(p.ds.test_row(i)))
                    .collect();
                let mut sim = PipelineSim::new(&model.net, &model.tables, strategy);
                let res = sim.stream(&inputs);
                assert_eq!(
                    res.latency_cycles, r.cycles,
                    "{id} {sname}: simulated cycles disagree with the model"
                );
                rows.push(vec![
                    d.to_string(),
                    format!("{}x{a}", p.man.config.fan[1]),
                    sname.into(),
                    format!("{:.0}", r.fmax_mhz),
                    r.cycles.to_string(),
                    format!("{:.0}", r.latency_ns),
                ]);
            }

            // Engine comparison on a 1k-sample batch: naive per-sample walk
            // vs the evaluation plan vs the bitsliced 64-lane engine.
            let lsim = model.sim();
            let batch: Vec<Vec<i32>> = (0..1000)
                .map(|i| model.net.quantize_input(p.ds.test_row(i % p.ds.n_test())))
                .collect();
            let t0 = Instant::now();
            let naive: usize =
                batch.iter().map(|c| lsim.forward_codes_reference(c).len()).sum();
            let t_naive = t0.elapsed().as_secs_f64();
            let mut scratch = Scratch::for_plan(&model.plan);
            let t1 = Instant::now();
            let planned = model.plan.forward_batch(&batch, &mut scratch).len();
            let t_plan = t1.elapsed().as_secs_f64();
            assert_eq!(naive / model.plan.n_outputs(), planned);
            let mut bscratch = model.bitslice.scratch();
            let t2 = Instant::now();
            let bitsliced = model.bitslice.forward_batch(&batch, &mut bscratch);
            let t_bits = t2.elapsed().as_secs_f64();
            assert_eq!(
                bitsliced,
                model.plan.forward_batch(&batch, &mut scratch),
                "{id}: bitslice disagrees with the plan"
            );
            eprintln!(
                "[table5] {id} software twin, 1k samples: naive {:.0}/s vs plan {:.0}/s ({:.2}x) vs bitslice {:.0}/s ({:.2}x vs plan)",
                1000.0 / t_naive,
                1000.0 / t_plan,
                t_naive / t_plan,
                1000.0 / t_bits,
                t_plan / t_bits
            );
        }
    }
    table(
        "Table V — pipeline strategies on JSC-M Lite (cycles validated by cycle-accurate sim)",
        &["D", "fan-in FxA", "strategy", "F_max MHz", "cycles", "latency ns"],
        &rows,
    );
}
