//! Table III — comparison with prior work at iso-accuracy: PolyLUT-Add with
//! the smaller-(F, D) Table IV setups vs PolyLUT (published, larger D),
//! LogicNets (implemented: A=1 D=1 in this framework), FINN, hls4ml,
//! Duarte, Fahim, Murovic (published + our analytic models).
//!
//!   cargo bench --bench table3_prior
//!
//! Shape expectation: for comparable accuracy PolyLUT-Add cuts LUTs by
//! ~4.6x / 5.0x / 7.7x / 1.3x vs PolyLUT on HDR / JSC-XL / JSC-M Lite /
//! NID and decreases latency 1.2-2.2x.

use polylut_add::fpga::baselines::{bnn_mlp_model, hls_mlp_model, published_rows};
use polylut_add::fpga::Strategy;
use polylut_add::harness;
use polylut_add::runtime::Engine;
use polylut_add::util::bench::table;

fn main() {
    let engine = Engine::cpu().expect("PJRT CPU client");
    // (table-IV artifact id, dataset tag, the published PolyLUT row name)
    let ours = [
        ("hdr-t4-d3-a2", "mnist", "PolyLUT (HDR, D=4)"),
        ("jsc-xl-t4-d3-a2", "jsc", "PolyLUT (JSC-XL, D=4)"),
        ("jsc-m-lite-t4-d3-a2", "jsc-lite", "PolyLUT (JSC-M Lite, D=6)"),
        ("nid-t4-d1-a2", "nid", "PolyLUT (NID-Lite, D=4)"),
    ];
    let published = published_rows();
    let mut rows = Vec::new();
    for (id, dataset, polylut_row) in ours {
        let p = match harness::prepare(&engine, id) {
            Ok(p) => p,
            Err(e) => {
                eprintln!("skip {id}: {e:#}");
                continue;
            }
        };
        // Lowest-latency configuration (strategy 2), as in the paper.
        let r = harness::synth(&p, Strategy::Merged).expect("synth");
        rows.push(vec![
            dataset.into(),
            format!("PolyLUT-Add ({id})"),
            harness::pct(p.accuracy),
            r.luts.to_string(),
            r.ffs.to_string(),
            "0".into(),
            "0".into(),
            format!("{:.0}", r.fmax_mhz),
            format!("{:.0}", r.latency_ns),
            "measured".into(),
        ]);
        // LUT reduction factor vs the published PolyLUT row.
        if let Some(pl) = published.iter().find(|r| r.system == polylut_row) {
            println!(
                "{dataset}: LUT reduction vs {} = {:.1}x, latency {:.1}x (paper: see Table III)",
                pl.system,
                pl.luts as f64 / r.luts as f64,
                pl.latency_ns / r.latency_ns
            );
        }
        for b in published.iter().filter(|r| r.dataset == dataset) {
            rows.push(vec![
                dataset.into(),
                b.system.into(),
                format!("{:.0}", b.accuracy_pct),
                b.luts.to_string(),
                b.ffs.to_string(),
                b.dsps.to_string(),
                b.brams.to_string(),
                format!("{:.0}", b.fmax_mhz),
                format!("{:.0}", b.latency_ns),
                b.provenance.into(),
            ]);
        }
    }
    // Our analytic comparator models on the paper geometries (ablation aid).
    for m in [
        bnn_mlp_model(&[784, 1024, 1024, 1024, 10], 16, 200.0),
        hls_mlp_model(&[16, 64, 32, 32, 5], 16, 1, 200.0),
    ] {
        rows.push(vec![
            "-".into(),
            m.system.into(),
            "-".into(),
            m.luts.to_string(),
            m.ffs.to_string(),
            m.dsps.to_string(),
            m.brams.to_string(),
            format!("{:.0}", m.fmax_mhz),
            format!("{:.0}", m.latency_ns),
            m.provenance.into(),
        ]);
    }
    table(
        "Table III — comparison with prior works (measured = this repo on the xcvu9p model; published = cited papers)",
        &[
            "dataset", "system", "acc %", "LUT", "FF", "DSP", "BRAM", "F_max MHz",
            "latency ns", "provenance",
        ],
        &rows,
    );
}
