//! Fig. 6 — accuracy of PolyLUT vs PolyLUT-Deeper(𝔻) vs PolyLUT-Wider(𝕎)
//! vs PolyLUT-Add(A) across HDR / JSC-XL / JSC-M Lite / NID Lite, D ∈ {1,2}.
//!
//!   cargo bench --bench fig6_accuracy
//!
//! Trains each configuration through the Rust PJRT driver (cached as
//! `<id>.weights.json`; POLYLUT_STEPS controls the budget) and reports
//! deployed-semantics test accuracy.  The paper's claim is the *ordering*:
//! Add ≥ base, Deeper, Wider at iso-(D, F).

use polylut_add::harness;
use polylut_add::runtime::Engine;
use polylut_add::util::bench::table;

struct Panel {
    model: &'static str,
    degree: u32,
    variants: Vec<(&'static str, String)>, // (label, artifact id)
}

fn panels() -> Vec<Panel> {
    let mut out = Vec::new();
    for (model, adds) in [
        ("hdr", vec![2, 3]),
        ("jsc-xl", vec![2]),
        ("jsc-m-lite", vec![2, 3]),
    ] {
        for degree in [1u32, 2] {
            let mut variants = vec![
                ("PolyLUT", format!("{model}-d{degree}-a1")),
                ("Deep(D=2)", format!("{model}-deep2-d{degree}-a1")),
                ("Wide(W=2)", format!("{model}-wide2-d{degree}-a1")),
            ];
            for &a in &adds {
                variants.push((
                    if a == 2 { "Add(A=2)" } else { "Add(A=3)" },
                    format!("{model}-d{degree}-a{a}"),
                ));
            }
            out.push(Panel { model, degree, variants });
        }
    }
    out.push(Panel {
        model: "nid-lite",
        degree: 1,
        variants: vec![
            ("PolyLUT", "nid-lite-d1-a1".into()),
            ("Deep(D=2)", "nid-lite-deep2-d1-a1".into()),
            ("Wide(W=2)", "nid-lite-wide2-d1-a1".into()),
            ("Add(A=2)", "nid-lite-d1-a2".into()),
        ],
    });
    out
}

fn main() {
    let engine = Engine::cpu().expect("PJRT CPU client");
    let mut rows = Vec::new();
    let mut add_wins = 0usize;
    let mut comparisons = 0usize;
    for panel in panels() {
        let mut base_acc = None;
        let mut best_add: f64 = 0.0;
        for (label, id) in &panel.variants {
            let acc = match harness::prepare(&engine, id) {
                Ok(p) => p.accuracy,
                Err(e) => {
                    eprintln!("skip {id}: {e:#}");
                    continue;
                }
            };
            eprintln!("[fig6] {id}: {acc:.4}");
            if *label == "PolyLUT" {
                base_acc = Some(acc);
            }
            if label.starts_with("Add") {
                best_add = best_add.max(acc);
            }
            rows.push(vec![
                panel.model.to_string(),
                format!("D={}", panel.degree),
                label.to_string(),
                harness::pct(acc),
            ]);
        }
        if let Some(base) = base_acc {
            if best_add > 0.0 {
                comparisons += 1;
                if best_add >= base {
                    add_wins += 1;
                }
            }
        }
    }
    table(
        "Fig. 6 — accuracy (%) by model / degree / variant (synthetic datasets; DESIGN.md §4-5)",
        &["model", "degree", "variant", "accuracy %"],
        &rows,
    );
    println!(
        "PolyLUT-Add beats/matches PolyLUT base in {add_wins}/{comparisons} panels (paper: all)"
    );
}
