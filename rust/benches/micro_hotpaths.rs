//! Micro-benchmarks of the hot paths (§Perf in EXPERIMENTS.md):
//! truth-table generation, LUT6 mapping, LUT-network inference, the
//! serving round-trip, PJRT eval-batch and train-step execution.
//!
//!   cargo bench --bench micro_hotpaths
//!
//! POLYLUT_BENCH_QUICK=1 trims budgets.

use std::sync::Arc;
use std::time::Duration;

use polylut_add::coordinator::{BackendSpec, FrozenModel, Server, ServerConfig};
use polylut_add::fpga::Strategy;
use polylut_add::harness;
use polylut_add::lut::tables::compile_neuron;
use polylut_add::runtime::Engine;
use polylut_add::sim::LutSim;
use polylut_add::util::bench::Bench;
use polylut_add::util::pool::default_workers;

fn main() {
    let engine = Engine::cpu().expect("PJRT CPU client");
    let b = Bench::default();
    let p = harness::prepare(&engine, "jsc-m-lite-d1-a2").expect("prepare quickstart model");
    let net = &p.net;

    // L3 hot path 1: truth-table generation.
    b.measure("tables/neuron (2^12 poly x2 + 2^8 adder)", || compile_neuron(net, 0, 0));
    let tables = polylut_add::lut::compile_network(net, default_workers());
    b.measure("tables/network (303 tables, parallel)", || {
        polylut_add::lut::compile_network(net, default_workers())
    });

    // L3 hot path 2: LUT6 technology mapping.
    b.measure("map/network (LUT6, parallel)", || {
        polylut_add::lut::map_network_of(net, &tables, default_workers())
    });

    // L3 hot path 3: LUT-network inference.
    let sim = LutSim::new(net, &tables);
    let x = p.ds.test_row(0).to_vec();
    let codes = net.quantize_input(&x);
    let st = b.measure("lutsim/forward (1 sample)", || sim.forward_codes(&codes));
    println!(
        "  -> {:.0} samples/s single-thread",
        st.throughput(1.0)
    );

    // Fixed-point float model for comparison.
    b.measure("network/forward (float fixed-point)", || net.forward(&x));

    // Serving round-trip (batched under load arrives in the server bench;
    // here: single in-flight request latency floor).
    let model = Arc::new(FrozenModel::from_network(net.clone(), default_workers()));
    let server = Server::start(
        BackendSpec::lut(model, default_workers()),
        p.man.config.n_classes,
        ServerConfig { max_batch: 64, window: Duration::from_micros(50), queue_cap: 1024 },
    );
    let client = server.client();
    b.measure("server/round-trip (1 in-flight)", || client.infer(x.clone()).unwrap());
    server.shutdown();

    // PJRT paths.
    let exe = engine.load_hlo(&p.man.eval_hlo).expect("eval hlo");
    let n_params = p
        .man
        .state
        .iter()
        .filter(|s| matches!(s.role, polylut_add::meta::Role::Train | polylut_add::meta::Role::Stat))
        .count();
    let args: Vec<xla::Literal> = p
        .man
        .state
        .iter()
        .zip(&p.state)
        .take(n_params)
        .map(|(spec, vals)| {
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            polylut_add::runtime::f32_literal(vals, &dims).unwrap()
        })
        .collect();
    let bsz = p.man.eval_batch;
    let mut flat = Vec::new();
    for i in 0..bsz {
        flat.extend_from_slice(p.ds.test_row(i % p.ds.n_test()));
    }
    let xlit =
        polylut_add::runtime::f32_literal(&flat, &[bsz as i64, p.ds.n_features as i64]).unwrap();
    let st = b.measure("pjrt/eval_batch (Pallas-lowered, 256)", || {
        let mut a: Vec<xla::Literal> = args
            .iter()
            .map(|l| {
                let dims: Vec<i64> = l.array_shape().unwrap().dims().to_vec();
                polylut_add::runtime::f32_literal(&l.to_vec::<f32>().unwrap(), &dims).unwrap()
            })
            .collect();
        a.push(
            polylut_add::runtime::f32_literal(&flat, &[bsz as i64, p.ds.n_features as i64])
                .unwrap(),
        );
        exe.run(&a).unwrap()
    });
    println!("  -> {:.0} samples/s via PJRT", st.throughput(bsz as f64));
    let _ = xlit;

    // FPGA back-end synthesis end to end.
    b.measure("fpga/synthesize (tables+map+report)", || {
        polylut_add::fpga::synthesize(net, Strategy::Merged).unwrap()
    });
}
