//! Micro-benchmarks of the hot paths (§Perf in EXPERIMENTS.md):
//! truth-table generation, LUT6 mapping, LUT-network inference
//! (naive reference vs the compiled evaluation plan, single-sample and
//! batched), the serving round-trip, and — when artifacts + the native PJRT
//! runtime are available — eval-batch execution.
//!
//!   cargo bench --bench micro_hotpaths
//!
//! POLYLUT_BENCH_QUICK=1 trims budgets.  Without `make artifacts` (or on an
//! image without xla_extension) the model falls back to a random-weight
//! JSC-M Lite network and the PJRT section is skipped — the LUT-path
//! numbers, including the plan-vs-naive comparison the acceptance criteria
//! track, are unaffected.

// Benches are a separate crate: clippy's allow-unwrap-in-tests doesn't
// reach them, so the workspace unwrap_used deny is lifted per-file.
#![allow(clippy::unwrap_used)]

use std::sync::Arc;
use std::time::Duration;

use polylut_add::coordinator::{BackendSpec, FrozenModel, Server, ServerConfig};
use polylut_add::fpga::Strategy;
use polylut_add::harness;
use polylut_add::lut::tables::compile_neuron;
use polylut_add::nn::config;
use polylut_add::nn::network::Network;
use polylut_add::runtime::Engine;
use polylut_add::sim::{
    verify, BitsliceNet, EvalPlan, LutSim, Scratch, ShardPlacement, ShardWorkerHost,
    ShardedModel, WireConfig, DEFAULT_WIRE_WINDOW,
};
use polylut_add::simd::{self, KernelPath, LanePlan, SimdLevel};
use polylut_add::util::bench::{Bench, BenchJournal};
use polylut_add::util::pool::default_workers;
use polylut_add::util::rng::Rng;

/// The SIMD-width bench ladder: every portable block width plus whatever
/// accelerated paths [`simd::plan_for`] selects on this host (deduplicated
/// by kernel path, so an AVX-less host doesn't measure Blocks4 twice).
fn width_ladder() -> Vec<LanePlan> {
    let mut plans = vec![
        LanePlan { lanes: 128, path: KernelPath::Blocks2, level: SimdLevel::Portable },
        LanePlan { lanes: 256, path: KernelPath::Blocks4, level: SimdLevel::Portable },
        LanePlan { lanes: 512, path: KernelPath::Blocks8, level: SimdLevel::Portable },
    ];
    for lanes in [128usize, 256, 512] {
        let p = simd::plan_for(lanes);
        if plans.iter().all(|q| q.path != p.path) {
            plans.push(p);
        }
    }
    plans
}

fn main() {
    let b = Bench::default();
    let mut journal = BenchJournal::new();
    println!(
        "[micro] simd: detected {} (widest {} lanes)",
        simd::detect_level().as_str(),
        simd::widest_lanes()
    );
    let engine = Engine::cpu().ok();
    let prepared = engine.as_ref().and_then(|e| {
        harness::prepare(e, "jsc-m-lite-d1-a2")
            .map_err(|err| eprintln!("[micro] no trained artifacts ({err:#})"))
            .ok()
    });

    // Trained network when available, random-weight JSC-M Lite otherwise —
    // identical geometry either way, so the hot-path shapes are the same.
    let (net, rows): (Network, Vec<Vec<f32>>) = match &prepared {
        Some(p) => {
            let rows =
                (0..1000).map(|i| p.ds.test_row(i % p.ds.n_test()).to_vec()).collect();
            (p.net.clone(), rows)
        }
        None => {
            eprintln!("[micro] falling back to a random-weight jsc-m-lite (D=1, A=2) network");
            let cfg = config::jsc_m_lite(1, 2);
            let net = Network::random(&cfg, &mut Rng::new(0xBEEF));
            let mut rng = Rng::new(7);
            let rows = (0..1000)
                .map(|_| (0..cfg.widths[0]).map(|_| rng.f32()).collect())
                .collect();
            (net, rows)
        }
    };

    // L3 hot path 1: truth-table generation.
    b.measure("tables/neuron (2^12 poly x2 + 2^8 adder)", || compile_neuron(&net, 0, 0));
    let tables = polylut_add::lut::compile_network(&net, default_workers());
    b.measure("tables/network (parallel)", || {
        polylut_add::lut::compile_network(&net, default_workers())
    });

    // L3 hot path 2: LUT6 technology mapping (bind one mapping for the
    // bitslice engine below instead of re-mapping there).
    let mapped = polylut_add::lut::map_network_of(&net, &tables, default_workers());
    b.measure("map/network (LUT6, parallel)", || {
        polylut_add::lut::map_network_of(&net, &tables, default_workers())
    });

    // L3 hot path 3: LUT-network inference — naive reference vs the plan.
    let sim = LutSim::new(&net, &tables);
    let plan = sim.plan();
    let x = rows[0].clone();
    let codes = net.quantize_input(&x);
    let code_rows: Vec<Vec<i32>> = rows.iter().map(|r| net.quantize_input(r)).collect();

    let st_naive1 = b.measure("lutsim-reference/forward (1 sample)", || {
        sim.forward_codes_reference(&codes)
    });
    println!("  -> {:.0} samples/s single-thread (naive)", st_naive1.throughput(1.0));
    let mut scratch = Scratch::for_plan(plan);
    let st_plan1 = b.measure("plan/forward (1 sample, scratch reuse)", || {
        plan.forward_codes_into(&codes, &mut scratch).len()
    });
    println!("  -> {:.0} samples/s single-thread (plan)", st_plan1.throughput(1.0));

    // The acceptance comparison: 1k-sample batch, plan vs per-sample naive.
    let st_naive = b.measure("lutsim-reference/forward x1000 (per-sample)", || {
        code_rows.iter().map(|c| sim.forward_codes_reference(c).len()).sum::<usize>()
    });
    let mut scratch2 = Scratch::for_plan(plan);
    let st_batch = b.measure("plan/forward_batch x1000 (blocked, 1 thread)", || {
        plan.forward_batch(&code_rows, &mut scratch2).len()
    });
    let st_batch_mt = b.measure("plan/forward_batch_f32 x1000 (blocked, parallel)", || {
        plan.forward_batch_f32(&rows, default_workers()).len()
    });
    println!(
        "  -> plan speedup vs naive on 1k batch: {:.2}x single-thread, {:.2}x with {} workers",
        st_naive.median_ns / st_batch.median_ns,
        st_naive.median_ns / st_batch_mt.median_ns,
        default_workers()
    );

    // Bitsliced 64-lane engine on the same (deep-table, βF=12) geometry:
    // honest crossover data — the plan's cache-resident table reads are hard
    // to beat when each table bit maps to ~2^{βF-6} LUT6s.
    let bits = BitsliceNet::from_mapped(&net, &tables, &mapped);
    let bst = bits.stats();
    println!(
        "  bitslice engine: {} nodes, {} solo + {} grouped LUT ops ({} groups), {} mux ops",
        bst.nodes, bst.lut_ops, bst.grouped_luts, bst.groups, bst.mux_ops
    );
    let mut bscratch = bits.scratch();
    let st_bits = b.measure("bitslice/forward_batch x1000 (64-lane, 1 thread)", || {
        bits.forward_batch(&code_rows, &mut bscratch).len()
    });
    println!(
        "  -> bitslice vs plan on 1k batch ({}, 2^12 tables): {:.2}x",
        net.cfg.name,
        st_batch.median_ns / st_bits.median_ns
    );
    journal.record(&net.cfg.name, "bitslice/scalar", 64, code_rows.len(), &st_bits);
    // One widest-lane point on the deep-table geometry (the full width
    // ladder runs on nid-t4 below, where the bitslice engine is the
    // design-point winner).
    let wplan = simd::plan_for(simd::widest_lanes());
    let bits_w = BitsliceNet::from_mapped(&net, &tables, &mapped).with_lane_plan(wplan);
    let st_bits_w = b.measure(
        &format!("bitslice/forward_batch x1000 ({}-lane {})", wplan.lanes, wplan.path.as_str()),
        || bits_w.forward_batch_codes(&code_rows).len(),
    );
    assert_eq!(
        bits_w.forward_batch_codes(&code_rows),
        bits.forward_batch(&code_rows, &mut bscratch),
        "wide bitslice disagrees with 64-lane on {}",
        net.cfg.name
    );
    journal.record(
        &net.cfg.name,
        &format!("bitslice/{}", wplan.path.as_str()),
        wplan.lanes,
        code_rows.len(),
        &st_bits_w,
    );

    // The acceptance comparison for the bitsliced engine: the paper's
    // Table IV Add2 geometry (small fan-in, βF = 6 → every table bit is a
    // single LUT6 — the design point PolyLUT-Add optimizes for).  1024
    // samples = 16 full 64-lane words, plan vs bitslice, single thread.
    let cfg4 = config::nid_add2();
    let net4 = Network::random(&cfg4, &mut Rng::new(0xADD2));
    let tables4 = polylut_add::lut::compile_network(&net4, default_workers());
    let plan4 = EvalPlan::compile(&net4, &tables4);
    let bits4 = BitsliceNet::compile(&net4, &tables4, default_workers());
    let mut rng4 = Rng::new(41);
    let rows4: Vec<Vec<i32>> = (0..1024)
        .map(|_| {
            let x: Vec<f32> = (0..cfg4.widths[0]).map(|_| rng4.f32()).collect();
            net4.quantize_input(&x)
        })
        .collect();
    let mut pscratch4 = Scratch::for_plan(&plan4);
    let st_plan4 = b.measure("plan/forward_batch x1024 (nid-t4, βF=6)", || {
        plan4.forward_batch(&rows4, &mut pscratch4).len()
    });
    let mut bscratch4 = bits4.scratch();
    let st_bits4 = b.measure("bitslice/forward_batch x1024 (nid-t4, βF=6)", || {
        bits4.forward_batch(&rows4, &mut bscratch4).len()
    });
    // Bit-exactness of the two engines on this batch (also pinned by tests).
    assert_eq!(
        bits4.forward_batch(&rows4, &mut bscratch4),
        plan4.forward_batch(&rows4, &mut pscratch4),
        "engines disagree on nid-t4"
    );
    println!(
        "  -> bitslice speedup vs plan on 1024-sample batch (nid-t4): {:.2}x ({:.0} vs {:.0} samples/s)",
        st_plan4.median_ns / st_bits4.median_ns,
        st_bits4.throughput(1024.0),
        st_plan4.throughput(1024.0)
    );
    journal.record("nid-t4", "plan", 0, rows4.len(), &st_plan4);
    journal.record("nid-t4", "bitslice/scalar", 64, rows4.len(), &st_bits4);

    // Netlist-opt acceptance line: the same engine on folded +
    // DC-rewritten op streams (the default serving pipeline) vs the
    // untouched compile above, pinned bit-exact on the same batch.
    let opt4 = polylut_add::lut::optimize(
        &net4,
        tables4.clone(),
        polylut_add::lut::OptLevel::FoldDc,
        default_workers(),
    );
    let bits4o = BitsliceNet::from_mapped(&net4, &opt4.tables, &opt4.mapped);
    let mut oscratch4 = bits4o.scratch();
    let st_bits4o = b.measure("bitslice/forward_batch x1024 (nid-t4, fold+dc)", || {
        bits4o.forward_batch(&rows4, &mut oscratch4).len()
    });
    assert_eq!(
        bits4o.forward_batch(&rows4, &mut oscratch4),
        plan4.forward_batch(&rows4, &mut pscratch4),
        "fold+dc must stay bit-exact on nid-t4"
    );
    println!(
        "  -> netlist-opt fold+dc (nid-t4): {} -> {} word-ops ({:.1}% saved), \
         bitslice {:.2}x samples/s vs unoptimized",
        opt4.report.ops_before(),
        opt4.report.ops_after(),
        opt4.report.reduction_pct(),
        st_bits4.median_ns / st_bits4o.median_ns
    );
    journal.record("nid-t4", "bitslice/fold+dc", 64, rows4.len(), &st_bits4o);

    // SIMD width ladder on nid-t4 — the tentpole acceptance sweep: one
    // op-stream walk retiring 128/256/512 samples via portable blocks and
    // the detected target_feature paths, each pinned bit-exact against the
    // 64-lane engine on the same batch.  1024 samples = 2 full 512-lane
    // words, so even the widest path runs full.
    let reference4 = bits4.forward_batch(&rows4, &mut bscratch4);
    let widest = simd::widest_lanes();
    let mut widest_ns = st_bits4.median_ns;
    for lp in width_ladder() {
        let wide = BitsliceNet::compile(&net4, &tables4, default_workers()).with_lane_plan(lp);
        let st = b.measure(
            &format!(
                "bitslice/forward_batch x1024 (nid-t4, {}-lane {})",
                lp.lanes,
                lp.path.as_str()
            ),
            || wide.forward_batch_codes(&rows4).len(),
        );
        assert_eq!(
            wide.forward_batch_codes(&rows4),
            reference4,
            "{}-lane {} path disagrees with 64-lane on nid-t4",
            lp.lanes,
            lp.path.as_str()
        );
        journal.record(
            "nid-t4",
            &format!("bitslice/{}", lp.path.as_str()),
            lp.lanes,
            rows4.len(),
            &st,
        );
        println!(
            "  -> {}-lane {} vs 64-lane scalar (nid-t4): {:.2}x ({:.0} samples/s)",
            lp.lanes,
            lp.path.as_str(),
            st_bits4.median_ns / st.median_ns,
            st.throughput(rows4.len() as f64)
        );
        if lp == simd::plan_for(widest) {
            widest_ns = st.median_ns;
        }
    }
    println!(
        "  -> widest path ({} lanes) vs 64-lane baseline on nid-t4: {:.2}x samples/s",
        widest,
        st_bits4.median_ns / widest_ns
    );

    // Sharded intra-sample execution on the same Table IV geometry: the
    // acceptance comparison is single-sample latency, sharded (S workers,
    // fan-in-aware early start over bit-plane/code handoff buffers) vs the
    // unsharded plan.  The whole-batch runs double as a bit-exactness check
    // against both existing engines on this geometry.
    let shard_n = default_workers().clamp(2, 4);
    let sharded4 = ShardedModel::compile(&net4, &tables4, shard_n, default_workers());
    println!(
        "  sharded engines: S={shard_n}, bitslice cone replication {:.2}x",
        sharded4.bits.replication()
    );

    // Static-verification pass cost on the same geometry — the price of the
    // always-on debug / POLYLUT_VERIFY release compile gate, one timing
    // line per artifact kind (see ARCHITECTURE.md §8).
    let arts4 = verify::compile_sharded_artifacts(&net4, &tables4, shard_n, default_workers());
    b.measure("verify/plan (nid-t4)", || verify::verify_plan(&plan4).len());
    b.measure("verify/op-streams (nid-t4)", || {
        verify::verify_bitslice(&bits4).len() + verify::verify_shard_streams(&arts4).len()
    });
    b.measure("verify/hazard-schedules (nid-t4)", || verify::verify_hazards(&arts4).len());
    b.measure("verify/wire-plans (nid-t4)", || verify::verify_wire_plans(&arts4).len());
    assert!(
        verify::verify_frozen(&plan4, &bits4).is_clean()
            && verify::verify_sharded(&arts4).is_clean(),
        "nid-t4 artifacts fail static verification"
    );
    let single = rows4[0].clone();
    let st_plan_1 = b.measure("plan/forward (1 sample, nid-t4)", || {
        plan4.forward_codes_into(&single, &mut pscratch4).len()
    });
    let st_shard_1 = b.measure("shard-plan/forward (1 sample, nid-t4)", || {
        sharded4.plan.forward_codes(&single).unwrap().len()
    });
    println!(
        "  -> sharded vs unsharded single-sample latency (nid-t4, S={shard_n}): {:.2}x ({} vs {})",
        st_plan_1.median_ns / st_shard_1.median_ns,
        polylut_add::util::bench::fmt_ns(st_shard_1.median_ns),
        polylut_add::util::bench::fmt_ns(st_plan_1.median_ns),
    );
    let st_shard_bits = b.measure("shard-bitslice/forward_batch x1024 (nid-t4)", || {
        sharded4.bits.forward_batch(&rows4).unwrap().len()
    });
    println!(
        "  -> sharded vs unsharded bitslice on 1024-sample batch (nid-t4): {:.2}x",
        st_bits4.median_ns / st_shard_bits.median_ns
    );
    // Bit-exactness of the sharded engines on this batch (also pinned by
    // the sim::shard test grid).
    assert_eq!(
        sharded4.plan.forward_batch(&rows4).unwrap(),
        plan4.forward_batch(&rows4, &mut pscratch4),
        "sharded plan disagrees on nid-t4"
    );
    assert_eq!(
        sharded4.bits.forward_batch(&rows4).unwrap(),
        bits4.forward_batch(&rows4, &mut bscratch4),
        "sharded bitslice disagrees on nid-t4"
    );
    let shard_stats = sharded4.stats();
    let cells: Vec<u64> = shard_stats.iter().map(|s| s.cells).collect();
    let waits: Vec<u64> = shard_stats.iter().map(|s| s.waits).collect();
    println!("  shard occupancy (cells) {cells:?}, handoff waits {waits:?}");

    // Wire handoff over loopback TCP (ROADMAP levers (d)/(e)): same
    // geometry and shard count, but the last shard is hosted by an
    // in-process `ShardWorkerHost` behind 127.0.0.1.  Two comparison
    // points: LocalHandoff vs loopback RemoteHandoff (the honest cost of
    // crossing a socket at all), and — the wire handoff v2 acceptance
    // point — the windowed stream (W = DEFAULT_WIRE_WINDOW) vs the v1
    // lock-step pacing (W = 1), which paid 2·L strictly-alternating frame
    // round-trips per sample on this 5-layer geometry.
    let host = Arc::new(ShardWorkerHost::compile(&net4, &tables4, shard_n, default_workers()));
    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().expect("local addr").to_string();
    {
        let host = host.clone();
        std::thread::spawn(move || host.serve(listener));
    }
    let placement: ShardPlacement =
        (0..shard_n).map(|s| (s + 1 == shard_n).then(|| addr.clone())).collect();
    let lockstep = ShardedModel::compile_placed_wire(
        &net4,
        &tables4,
        shard_n,
        default_workers(),
        &placement,
        None,
        WireConfig::lock_step(),
    )
    .expect("loopback shard worker (lock-step)");
    let st_wire_lock =
        b.measure("shard-plan/forward (1 sample, nid-t4, loopback, lock-step W=1)", || {
            lockstep.plan.forward_codes(&single).unwrap().len()
        });
    // Bit-exactness under lock-step pacing, then drop it so the windowed
    // model below owns the comparison.
    assert_eq!(
        lockstep.plan.forward_batch(&rows4[..70]).unwrap(),
        plan4.forward_batch(&rows4[..70], &mut pscratch4),
        "lock-step wired plan disagrees on nid-t4"
    );
    drop(lockstep);
    let wired = ShardedModel::compile_placed(
        &net4,
        &tables4,
        shard_n,
        default_workers(),
        &placement,
        None,
    )
    .expect("loopback shard worker (windowed)");
    let st_wire_1 = b.measure(
        "shard-plan/forward (1 sample, nid-t4, loopback, windowed W=4)",
        || wired.plan.forward_codes(&single).unwrap().len(),
    );
    println!(
        "  -> windowed (W={DEFAULT_WIRE_WINDOW}) vs lock-step (W=1) single-sample over loopback (nid-t4, S={shard_n}): {:.2}x ({} vs {})",
        st_wire_lock.median_ns / st_wire_1.median_ns,
        polylut_add::util::bench::fmt_ns(st_wire_1.median_ns),
        polylut_add::util::bench::fmt_ns(st_wire_lock.median_ns),
    );
    println!(
        "  -> LocalHandoff vs loopback RemoteHandoff single-sample (nid-t4, S={shard_n}, windowed): {:.2}x ({} vs {})",
        st_wire_1.median_ns / st_shard_1.median_ns,
        polylut_add::util::bench::fmt_ns(st_shard_1.median_ns),
        polylut_add::util::bench::fmt_ns(st_wire_1.median_ns),
    );
    // Bit-exactness across the wire (also pinned by the sim::wire tests).
    assert_eq!(
        wired.plan.forward_batch(&rows4[..70]).unwrap(),
        plan4.forward_batch(&rows4[..70], &mut pscratch4),
        "wired plan disagrees on nid-t4"
    );
    assert_eq!(
        wired.bits.forward_batch(&rows4[..64]).unwrap(),
        bits4.forward_batch(&rows4[..64], &mut bscratch4),
        "wired bitslice disagrees on nid-t4"
    );
    let ws = wired.wire_stats().expect("remote link present");
    println!(
        "  wire link: {} frames, {} bytes, {:.2} ms blocked, {} reconnects, {} resumes, inflight hwm {} (spin_us={})",
        ws.frames,
        ws.bytes,
        ws.wait_ns as f64 / 1e6,
        ws.reconnects,
        ws.resumes,
        ws.inflight_hwm,
        wired.spin_us()
    );
    drop(wired);
    drop(sharded4);

    // Fixed-point float model for comparison.
    b.measure("network/forward (float fixed-point)", || net.forward(&x));

    // Serving round-trip (batched under load arrives in the server bench;
    // here: single in-flight request latency floor).
    let model = Arc::new(FrozenModel::from_network(net.clone(), default_workers()));
    let server = Server::start(
        BackendSpec::lut(model, default_workers()),
        net.cfg.n_classes,
        ServerConfig {
            max_batch: 64,
            window: Duration::from_micros(50),
            queue_cap: 1024,
            ..Default::default()
        },
    );
    let client = server.client();
    b.measure("server/round-trip (1 in-flight)", || client.infer(x.clone()).unwrap());
    server.shutdown();

    // PJRT paths — only with a native runtime and trained artifacts.
    if let (Some(engine), Some(p)) = (&engine, &prepared) {
        let exe = engine.load_hlo(&p.man.eval_hlo).expect("eval hlo");
        let n_params = p
            .man
            .state
            .iter()
            .filter(|s| {
                matches!(s.role, polylut_add::meta::Role::Train | polylut_add::meta::Role::Stat)
            })
            .count();
        let args: Vec<xla::Literal> = p
            .man
            .state
            .iter()
            .zip(&p.state)
            .take(n_params)
            .map(|(spec, vals)| {
                let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
                polylut_add::runtime::f32_literal(vals, &dims).unwrap()
            })
            .collect();
        let bsz = p.man.eval_batch;
        let mut flat = Vec::new();
        for i in 0..bsz {
            flat.extend_from_slice(p.ds.test_row(i % p.ds.n_test()));
        }
        let st = b.measure("pjrt/eval_batch (Pallas-lowered)", || {
            let mut a: Vec<xla::Literal> = args
                .iter()
                .map(|l| {
                    let dims: Vec<i64> = l.array_shape().unwrap().dims().to_vec();
                    polylut_add::runtime::f32_literal(&l.to_vec::<f32>().unwrap(), &dims)
                        .unwrap()
                })
                .collect();
            a.push(
                polylut_add::runtime::f32_literal(
                    &flat,
                    &[bsz as i64, p.ds.n_features as i64],
                )
                .unwrap(),
            );
            exe.run(&a).unwrap()
        });
        println!("  -> {:.0} samples/s via PJRT", st.throughput(bsz as f64));
    } else {
        eprintln!("[micro] PJRT section skipped (no native runtime / artifacts)");
    }

    // FPGA back-end synthesis end to end.
    b.measure("fpga/synthesize (tables+map+report)", || {
        polylut_add::fpga::synthesize(&net, Strategy::Merged).unwrap()
    });

    // Machine-readable throughput records (BENCH_bitslice.json in CI) —
    // written only when POLYLUT_BENCH_JSON names a path.
    journal.write_if_requested();
}
