//! Netlist-optimization pipeline bench (`lut::opt`): for each paper
//! geometry, compile the bitslice engine at level `none` vs the default
//! `fold+dc`, report the word-op delta and the measured samples/s at each
//! level, and pin bit-exactness of the optimized streams on the bench
//! batch.  With `POLYLUT_BENCH_JSON=BENCH_netlist.json` every point lands
//! in the journal as a `NetlistRecord` (marked by its `level` key) for
//! the CI asserts.
//!
//!   cargo bench --bench netlist_opt
//!
//! POLYLUT_BENCH_QUICK=1 trims budgets.  Random-weight networks — table
//! structure, mapping, and op counts don't depend on training.

#![allow(clippy::unwrap_used)]

use polylut_add::lut::{optimize, OptLevel};
use polylut_add::nn::config::{self, ModelConfig};
use polylut_add::nn::network::Network;
use polylut_add::sim::BitsliceNet;
use polylut_add::util::bench::{Bench, BenchJournal, NetlistRecord};
use polylut_add::util::pool::default_workers;
use polylut_add::util::rng::Rng;

const BATCH: usize = 1024;

fn geometries() -> Vec<(&'static str, ModelConfig)> {
    vec![("nid-t4", config::nid_add2()), ("jsc-m-lite-d1-a2", config::jsc_m_lite(1, 2))]
}

fn main() {
    let b = Bench::default();
    let mut journal = BenchJournal::new();
    let workers = default_workers();
    for (name, cfg) in geometries() {
        let net = Network::random(&cfg, &mut Rng::new(0x0907));
        let tables = polylut_add::lut::compile_network(&net, workers);
        let mut rng = Rng::new(17);
        let rows: Vec<Vec<i32>> = (0..BATCH)
            .map(|_| {
                let x: Vec<f32> = (0..cfg.widths[0]).map(|_| rng.f32()).collect();
                net.quantize_input(&x)
            })
            .collect();
        let mut reference: Option<Vec<Vec<i32>>> = None;
        for level in [OptLevel::None, OptLevel::FoldDc] {
            let opt = optimize(&net, tables.clone(), level, workers);
            let bits = BitsliceNet::from_mapped(&net, &opt.tables, &opt.mapped);
            let mut scratch = bits.scratch();
            let out = bits.forward_batch(&rows, &mut scratch);
            match &reference {
                None => reference = Some(out),
                Some(r) => assert_eq!(&out, r, "{name}: {level} must stay bit-exact"),
            }
            let st = b.measure(&format!("bitslice/forward_batch x{BATCH} ({name}, {level})"), || {
                bits.forward_batch(&rows, &mut scratch).len()
            });
            println!(
                "  -> {name} [{level}]: {} -> {} word-ops ({:.1}% saved), {:.0} samples/s",
                opt.report.ops_before(),
                opt.report.ops_after(),
                opt.report.reduction_pct(),
                st.throughput(BATCH as f64)
            );
            journal.record_netlist(NetlistRecord {
                geometry: name.to_string(),
                level: level.to_string(),
                ops_before: opt.report.ops_before(),
                ops_after: opt.report.ops_after(),
                samples_per_sec: st.throughput(BATCH as f64),
                median_ns: st.median_ns,
            });
        }
    }
    journal.write_if_requested();
}
