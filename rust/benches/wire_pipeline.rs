//! Wire v3 pipeline bench: streamed single-sample serving over loopback
//! TCP with remote shards, sweeping the epoch window {1, 4, 16} × link
//! multiplexing {off, on} on the nid-t4 geometry (ROADMAP §Perf, wire
//! handoff v3 acceptance point).
//!
//!   cargo bench --bench wire_pipeline
//!
//! Shape: S = 3 intra-sample shards, shard 0 local, shards 1 and 2 hosted
//! by ONE in-process `ShardWorkerHost` behind 127.0.0.1 — so with mux on,
//! a single TCP connection carries all four (engine, shard) sessions.
//! Eight closed-loop client threads stream single samples through the
//! sharded plan engine; W = 1 serializes them to one epoch in flight
//! (lock-step), W = 16 lets the epoch ring overlap their epochs
//! end-to-end.  Every sample is asserted bit-exact against
//! `Network::forward_codes` inside the measured pass, every config's link
//! topology and in-flight high-water mark are asserted after it, and the
//! W=16-vs-W=1 speedup is printed.  POLYLUT_BENCH_JSON=<path> writes the
//! records as a `polylut-bench-v1` journal (the CI bench leg emits
//! `BENCH_wire.json` and asserts the speedup > 1.0 from it).
//! POLYLUT_BENCH_QUICK=1 trims budgets.

// Benches are a separate crate: clippy's allow-unwrap-in-tests doesn't
// reach them, so the workspace unwrap_used deny is lifted per-file.
#![allow(clippy::unwrap_used)]

use std::sync::Arc;

use polylut_add::nn::config;
use polylut_add::nn::network::Network;
use polylut_add::sim::{
    ShardPlacement, ShardWorkerHost, ShardedModel, WireConfig, DEFAULT_WIRE_RETRIES,
};
use polylut_add::util::bench::{Bench, BenchJournal, Stats};
use polylut_add::util::pool::default_workers;
use polylut_add::util::rng::Rng;

/// Intra-sample shard count: shard 0 local, shards 1.. on the worker host.
const SHARDS: usize = 3;
/// Concurrent closed-loop client threads streaming single samples.
const STREAMS: usize = 8;

/// One measured pass: `STREAMS` clients stream the whole sample set
/// through the sharded plan engine, single sample per call, each answer
/// asserted bit-exact in-line.  Returns the samples retired.
fn stream_pass(model: &ShardedModel, xs: &[Vec<i32>], want: &[Vec<i32>]) -> usize {
    std::thread::scope(|scope| {
        for t in 0..STREAMS {
            scope.spawn(move || {
                let mut i = t;
                while i < xs.len() {
                    let got = model.plan.forward_codes(&xs[i]).expect("streamed serve");
                    assert_eq!(got, want[i], "sample {i} must stay bit-exact");
                    i += STREAMS;
                }
            });
        }
    });
    xs.len()
}

fn main() {
    let quick = std::env::var("POLYLUT_BENCH_QUICK").is_ok();
    let b = Bench::default();
    let mut journal = BenchJournal::new();

    let cfg = config::nid_add2();
    let net = Network::random(&cfg, &mut Rng::new(0x317E));
    let tables = polylut_add::lut::compile_network(&net, default_workers());

    // One in-process worker host on loopback carries both remote shards
    // (the `polylut shard-worker` process path is covered by the
    // wire_loopback integration test; in-process keeps the bench
    // self-contained and the socket cost identical).
    let host = Arc::new(ShardWorkerHost::compile(&net, &tables, SHARDS, default_workers()));
    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().expect("local addr").to_string();
    {
        let host = host.clone();
        std::thread::spawn(move || host.serve(listener));
    }
    let placement: ShardPlacement =
        (0..SHARDS).map(|s| (s > 0).then(|| addr.clone())).collect();

    let n_samples = if quick { 64 } else { 240 };
    let mut rng = Rng::new(7);
    let xs: Vec<Vec<i32>> = (0..n_samples)
        .map(|_| {
            let x: Vec<f32> = (0..cfg.widths[0]).map(|_| rng.f32()).collect();
            net.quantize_input(&x)
        })
        .collect();
    let want: Vec<Vec<i32>> = xs.iter().map(|x| net.forward_codes(x)).collect();

    let mut results: Vec<(usize, bool, Stats)> = Vec::new();
    for mux in [false, true] {
        for window in [1usize, 4, 16] {
            let wire = WireConfig { window, retries: DEFAULT_WIRE_RETRIES, mux };
            let model = ShardedModel::compile_placed_wire(
                &net,
                &tables,
                SHARDS,
                default_workers(),
                &placement,
                None,
                wire,
            )
            .expect("loopback shard worker");
            let label = format!("wire/W{window}/mux-{}", if mux { "on" } else { "off" });
            let st = b.measure(
                &format!("{label} stream x{n_samples} ({STREAMS} clients, S={SHARDS}, nid-t4)"),
                || stream_pass(&model, &xs, &want),
            );
            println!("  -> {:.0} samples/s streamed", st.throughput(n_samples as f64));

            assert!(!model.faulted(), "{label}: no degraded batches");
            let ws = model.wire_stats().expect("remote links present");
            assert_eq!(ws.retry_exhausted, 0, "{label}: {ws:?}");
            if window == 1 {
                assert_eq!(ws.inflight_epochs, 1, "{label} is lock-step: {ws:?}");
            } else {
                assert!(ws.inflight_epochs > 1, "{label} must overlap epochs: {ws:?}");
            }
            // Link topology: mux on folds all four (engine, shard)
            // sessions onto one TCP connection; off keeps the v2
            // one-connection-per-session shape.
            let sessions = 2 * (SHARDS - 1);
            if mux {
                assert_eq!(model.wire_links(), 1, "{label}: one TCP connection per host");
                let hosts = model.wire_host_stats();
                assert_eq!(hosts.len(), 1, "{label}: {hosts:?}");
                assert_eq!(hosts[0].sessions as usize, sessions, "{label}: {hosts:?}");
            } else {
                assert_eq!(model.wire_links(), sessions, "{label}: one link per session");
            }

            journal.record("nid-t4", &label, 0, n_samples, &st);
            results.push((window, mux, st));
        }
    }

    let median = |w: usize, m: bool| -> f64 {
        results
            .iter()
            .find(|(rw, rm, _)| *rw == w && *rm == m)
            .map(|(_, _, s)| s.median_ns)
            .expect("config measured")
    };
    // The v3 acceptance headline: end-to-end epoch pipelining at W=16 vs
    // lock-step W=1, both multiplexed.  Printed here; the CI bench leg
    // asserts > 1.0 from the journal so a loaded runner fails loudly
    // instead of silently shipping a regression.
    println!(
        "[wire] W=16 vs W=1 streamed speedup (mux on, {STREAMS} clients): {:.2}x",
        median(1, true) / median(16, true)
    );
    println!(
        "[wire] link mux on vs off at W=16: {:.2}x",
        median(16, false) / median(16, true)
    );
    println!(
        "[wire] W=4 (default) vs W=1 (mux on): {:.2}x",
        median(1, true) / median(4, true)
    );

    journal.write_if_requested();
}
