//! Network-intrusion-detection serving scenario (paper Sec. IV-A-3).
//!
//! Deploys the trained NID model behind the L3 inference coordinator and
//! drives a multi-client load test, comparing the two backends:
//! - `lut`  — deployed-semantics LUT-network evaluation (FPGA software twin)
//! - `pjrt` — the Pallas-lowered JAX eval graph through the PJRT runtime
//!
//!   cargo run --release --example nids_server [-- --requests 20000 --clients 8]

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;
use polylut_add::coordinator::{BackendSpec, FrozenModel, Server, ServerConfig};
use polylut_add::util::cli::Args;
use polylut_add::{harness, runtime::Engine};

fn main() -> Result<()> {
    let args = Args::from_env(&[])?;
    let n_requests = args.get_usize("requests", 20_000)?;
    let n_clients = args.get_usize("clients", 8)?;
    let id = args.get_or("id", "nid-t4-d1-a2").to_string();
    let engine = Engine::cpu()?;

    println!("== NIDS serving: {id} ==");
    let p = harness::prepare(&engine, &id)?;
    println!("deployed accuracy: {}% (UNSW-NB15 substitute)", harness::pct(p.accuracy));

    let model = Arc::new(FrozenModel::from_network(p.net.clone(), 8));
    for backend_name in ["lut", "pjrt"] {
        let spec = match backend_name {
            "lut" => BackendSpec::lut(model.clone(), polylut_add::util::pool::default_workers()),
            _ => BackendSpec::pjrt(p.man.clone(), p.state.clone()),
        };
        let server = Server::start(
            spec,
            p.man.config.n_classes,
            ServerConfig {
                max_batch: 256,
                window: Duration::from_micros(200),
                queue_cap: 8192,
                ..Default::default()
            },
        );
        let correct = Arc::new(AtomicU64::new(0));
        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for c in 0..n_clients {
                let client = server.client();
                let ds = &p.ds;
                let correct = correct.clone();
                scope.spawn(move || {
                    let per = n_requests / n_clients;
                    for i in 0..per {
                        let idx = (c * per + i) % ds.n_test();
                        if let Ok(resp) = client.infer(ds.test_row(idx).to_vec()) {
                            if resp.pred == ds.y_test[idx] {
                                correct.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                });
            }
        });
        let wall = t0.elapsed().as_secs_f64();
        let served = server.metrics.responses.load(Ordering::Relaxed);
        println!("\nbackend={backend_name}: {}", server.metrics.snapshot());
        println!(
            "backend={backend_name}: {:.0} req/s, serve accuracy {:.4}, wall {:.2}s",
            served as f64 / wall,
            correct.load(Ordering::Relaxed) as f64 / served.max(1) as f64,
            wall
        );
        server.shutdown();
    }
    Ok(())
}
