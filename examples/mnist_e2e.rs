//! End-to-end driver (DESIGN.md deliverable): the complete PolyLUT-Add
//! toolflow on the MNIST HDR model, proving all three layers compose —
//! JAX/Pallas AOT artifacts → Rust PJRT training → LUT compiler → LUT6
//! mapping → area/timing → Verilog RTL → bit-exact pipeline simulation.
//!
//!   cargo run --release --example mnist_e2e [-- --steps N --id hdr-t4-d3-a2]
//!
//! Logs the loss curve and records every stage; the run is summarized in
//! EXPERIMENTS.md §E2E.

use std::time::Instant;

use anyhow::Result;
use polylut_add::coordinator::FrozenModel;
use polylut_add::fpga::Strategy;
use polylut_add::sim::{LutSim, PipelineSim};
use polylut_add::util::cli::Args;
use polylut_add::{data, harness, meta, runtime::Engine, train, verilog};

fn main() -> Result<()> {
    let args = Args::from_env(&["verbose"])?;
    let id = args.get_or("id", "hdr-t4-d3-a2").to_string();
    let steps = args.get_usize("steps", harness::train_steps())?;
    let dir = harness::artifacts_dir();
    let engine = Engine::cpu()?;
    println!("== PolyLUT-Add end-to-end: {id} on synthetic MNIST ==");
    println!("platform: PJRT {}", engine.platform());

    // 1. Train via the AOT train_step (loss curve logged).
    let man = meta::load_id(&dir, &id)?;
    let ds = data::load(&man.dataset, 0)?;
    println!(
        "[1/6] training {} layers on {} ({} train / {} test), {} steps…",
        man.config.n_layers(),
        ds.name,
        ds.n_train(),
        ds.n_test(),
        steps
    );
    let t0 = Instant::now();
    let opts = train::TrainOptions {
        steps,
        log_every: (steps / 10).max(1),
        verbose: true,
        ..Default::default()
    };
    let (state, _) = train::train_or_load(&engine, &man, &ds, &opts)?;
    let net = man.network_from_state(&state)?;
    let (_, acc) = train::deployed_accuracy(&man, &state, &ds, 0)?;
    println!(
        "      deployed test accuracy {} % ({:.1}s)",
        harness::pct(acc),
        t0.elapsed().as_secs_f64()
    );

    // 2. Freeze into lookup tables.
    let t1 = Instant::now();
    let model = FrozenModel::from_network(net.clone(), polylut_add::util::pool::default_workers());
    println!(
        "[2/6] froze {} tables, {} words, in {:.2}s",
        model.tables.n_tables(),
        model.tables.total_words,
        t1.elapsed().as_secs_f64()
    );

    // 3. Technology-map + synthesize (both pipeline strategies).
    let t2 = Instant::now();
    let r2 = polylut_add::fpga::synthesize(&net, Strategy::Merged)?;
    let r1 = polylut_add::fpga::synthesize(&net, Strategy::SeparateRegisters)?;
    println!("[3/6] synthesis ({:.1}s):", t2.elapsed().as_secs_f64());
    println!("{}", r2.render());
    println!(
        "      strategy 1: F_max {:.0} MHz, {} cycles, {:.1} ns",
        r1.fmax_mhz, r1.cycles, r1.latency_ns
    );

    // 4. Emit RTL.
    let rtl_dir = std::env::temp_dir().join(format!("polylut_rtl_{id}"));
    let files = verilog::emit_project(&net, &rtl_dir)?;
    let bytes: u64 =
        files.iter().filter_map(|f| std::fs::metadata(f).ok()).map(|m| m.len()).sum();
    println!(
        "[4/6] wrote {} Verilog files ({:.1} MB) to {}",
        files.len(),
        bytes as f64 / 1e6,
        rtl_dir.display()
    );

    // 5. Bit-exact check: LUT simulator vs fixed-point model on test data.
    let sim = LutSim::new(&model.net, &model.tables);
    let n_check = 500.min(ds.n_test());
    let mut mismatches = 0;
    for i in 0..n_check {
        let codes = model.net.quantize_input(ds.test_row(i));
        if sim.forward_codes(&codes) != model.net.forward_codes(&codes) {
            mismatches += 1;
        }
    }
    println!(
        "[5/6] LUT network vs fixed-point model: {mismatches}/{n_check} mismatches (must be 0)"
    );
    assert_eq!(mismatches, 0);

    // 6. Cycle-accurate pipeline streaming at II=1.
    let inputs: Vec<Vec<i32>> = (0..200)
        .map(|i| model.net.quantize_input(ds.test_row(i % ds.n_test())))
        .collect();
    let mut pipe = PipelineSim::new(&model.net, &model.tables, Strategy::Merged);
    let res = pipe.stream(&inputs);
    let lut_acc = sim.accuracy(&ds, 2000);
    println!(
        "[6/6] pipeline: latency {} cycles (synth says {}), {} samples in {} cycles (II=1), LUT-sim acc {}%",
        res.latency_cycles,
        r2.cycles,
        inputs.len(),
        res.total_cycles,
        harness::pct(lut_acc)
    );
    assert_eq!(res.latency_cycles, r2.cycles);
    println!("\nE2E OK — all stages compose.");
    Ok(())
}
