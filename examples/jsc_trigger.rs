//! LHC jet-trigger scenario (paper Sec. IV-A-2): fixed-latency streaming
//! classification at initiation interval 1 — the FPGA use case the JSC
//! models target.  Streams jets through the cycle-accurate pipeline
//! simulator under both pipeline strategies (paper Fig. 5 / Table V) and
//! reports the trigger's latency and sustained throughput at the modelled
//! F_max.
//!
//!   cargo run --release --example jsc_trigger [-- --id jsc-m-lite-d1-a2]

use anyhow::Result;
use polylut_add::coordinator::FrozenModel;
use polylut_add::fpga::Strategy;
use polylut_add::sim::{LutSim, PipelineSim};
use polylut_add::util::cli::Args;
use polylut_add::{harness, runtime::Engine};

fn main() -> Result<()> {
    let args = Args::from_env(&[])?;
    let id = args.get_or("id", "jsc-m-lite-d1-a2").to_string();
    let n_jets = args.get_usize("jets", 5_000)?;
    let engine = Engine::cpu()?;

    println!("== JSC trigger: {id} ==");
    let p = harness::prepare(&engine, &id)?;
    println!("deployed accuracy: {}%", harness::pct(p.accuracy));
    let model = FrozenModel::from_network(p.net.clone(), 8);
    let sim = LutSim::new(&model.net, &model.tables);

    let inputs: Vec<Vec<i32>> = (0..n_jets)
        .map(|i| model.net.quantize_input(p.ds.test_row(i % p.ds.n_test())))
        .collect();

    for (strategy, label) in [
        (Strategy::SeparateRegisters, "strategy 1 (separate poly/adder regs)"),
        (Strategy::Merged, "strategy 2 (merged stage)"),
    ] {
        let report = harness::synth(&p, strategy)?;
        let mut pipe = PipelineSim::new(&model.net, &model.tables, strategy);
        let t0 = std::time::Instant::now();
        let res = pipe.stream(&inputs);
        let sim_wall = t0.elapsed().as_secs_f64();
        // Functional check against the LUT simulator.
        let ok = res
            .outputs
            .iter()
            .zip(&inputs)
            .all(|(out, inp)| out == &sim.forward_codes(inp));
        assert!(ok, "pipeline output mismatch");
        let ns_per_jet = 1000.0 / report.fmax_mhz;
        println!("\n{label}:");
        println!(
            "  latency {} cycles @ {:.0} MHz = {:.1} ns; II=1 -> {:.1} Mjets/s on-FPGA",
            res.latency_cycles,
            report.fmax_mhz,
            res.latency_cycles as f64 * ns_per_jet,
            report.fmax_mhz
        );
        println!(
            "  simulated {} jets in {} cycles ({:.2}s wall, {:.0} jets/s simulated)",
            n_jets,
            res.total_cycles,
            sim_wall,
            n_jets as f64 / sim_wall
        );
    }
    Ok(())
}
