//! Quickstart: the full PolyLUT-Add flow on JSC-M Lite in under a minute.
//!
//!   cargo run --release --example quickstart
//!
//! 1. Loads the AOT artifacts (JAX/Pallas lowered at `make artifacts`).
//! 2. Trains via the Rust-driven PJRT loop (or loads cached weights).
//! 3. Freezes the network into lookup tables, maps to LUT6s, and prints the
//!    paper-style area/timing report.
//! 4. Serves a few predictions through the LUT simulator.
use anyhow::Result;
use polylut_add::{fpga::Strategy, harness, runtime::Engine, sim::LutSim};

fn main() -> Result<()> {
    let engine = Engine::cpu()?;
    println!("== PolyLUT-Add quickstart (JSC-M Lite, D=1, A=2) ==");
    let p = harness::prepare(&engine, "jsc-m-lite-d1-a2")?;
    println!("deployed test accuracy: {}%", harness::pct(p.accuracy));

    let report = harness::synth(&p, Strategy::Merged)?;
    println!("\n{}", report.render());

    // Deployed-semantics inference through the frozen tables.
    let tables = polylut_add::lut::compile_network(&p.net, 4);
    let sim = LutSim::new(&p.net, &tables);
    println!("sample predictions (LUT network vs label):");
    for i in 0..8 {
        let pred = sim.predict(p.ds.test_row(i));
        println!("  jet {i}: predicted class {pred}, label {}", p.ds.y_test[i]);
    }

    // PolyLUT baseline (A=1) for comparison — the paper's headline.
    let base = harness::prepare(&engine, "jsc-m-lite-d1-a1")?;
    let base_report = harness::synth(&base, Strategy::Merged)?;
    println!(
        "\nPolyLUT-Add vs PolyLUT (iso-config): acc {}% vs {}%, LUT {} vs {} ({:.1}x)",
        harness::pct(p.accuracy),
        harness::pct(base.accuracy),
        report.luts,
        base_report.luts,
        report.luts as f64 / base_report.luts as f64
    );
    Ok(())
}
